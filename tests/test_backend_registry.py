"""RetrievalBackend registry: bit-identity vs the legacy entry points.

The refactor contract (PR-5 acceptance): the generic registry drivers
``toploc.start/step/plain(+_batch)/conversation`` produce *bit-identical*
scores, ids, sessions and ``TurnStats`` to the legacy prefixed clones
they replaced, for all three backends, across sequential, batched and
whole-conversation paths — and every legacy name now warns.
"""
import warnings

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import backend as B
from repro.core import toploc

K, H, NPROBE, EF, UP, RR, ALPHA = 10, 16, 4, 16, 2, 32, 0.3
BATCH = 4


def _legacy(fn, *args, **kwargs):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return fn(*args, **kwargs)


def _tree_equal(a, b, ctx=""):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), ctx
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=str(ctx))


# ----------------------------------------------------------- registry

def test_registry_lists_all_backends():
    assert set(B.names()) >= {"ivf", "ivf_pq", "hnsw", "exact"}
    assert B.get("ivf") is B.IVFBackend
    with pytest.raises(ValueError, match="unknown retrieval backend"):
        B.get("faiss")


def test_make_filters_unknown_knobs():
    bk = B.make("ivf", h=H, nprobe=NPROBE, alpha=ALPHA, rerank=99,
                ef=123, up=7)
    assert bk == B.IVFBackend(h=H, nprobe=NPROBE, alpha=ALPHA)
    pk = B.make("ivf_pq", h=H, nprobe=NPROBE, alpha=-1.0, rerank=RR,
                ef=123)
    assert pk.rerank == RR
    hk = B.make("hnsw", ef=EF, up=UP, h=H)
    assert hk == B.HNSWBackend(ef=EF, up=UP)


def test_make_rejects_knob_no_backend_declares():
    """Lenient filtering is for *cross-backend* knobs; a knob matching
    no registered backend's fields is a typo and must raise (the old
    silent drop turned ``nprob=16`` into a default-nprobe backend)."""
    with pytest.raises(TypeError, match="nprob"):
        B.make("ivf", h=H, nprob=NPROBE)
    with pytest.raises(TypeError, match="efSearch"):
        B.make("hnsw", efSearch=EF)


def test_make_strict_rejects_other_backends_knobs():
    """strict=True (user-facing callers) rejects knobs this backend
    doesn't declare itself, even valid knobs of *other* backends."""
    with pytest.raises(TypeError, match="strict"):
        B.make("ivf", h=H, nprobe=NPROBE, ef=EF, strict=True)
    assert B.make("ivf", h=H, nprobe=NPROBE, strict=True) == \
        B.IVFBackend(h=H, nprobe=NPROBE)


def test_backends_are_hashable_jit_static():
    """A backend is a static jit argument: equal knobs ⇒ equal hash ⇒
    one compiled program per configuration."""
    a = B.IVFBackend(h=H, nprobe=NPROBE)
    b = B.IVFBackend(h=H, nprobe=NPROBE)
    assert a == b and hash(a) == hash(b)
    assert a != B.IVFBackend(h=H, nprobe=NPROBE + 1)


def test_every_legacy_alias_warns(ivf_index, small_corpus):
    q0 = jnp.asarray(small_corpus.conversations[0, 0])
    with pytest.warns(DeprecationWarning, match="ivf_start is deprecated"):
        toploc.ivf_start(ivf_index, q0, h=H, nprobe=NPROBE, k=K)
    with pytest.warns(DeprecationWarning, match="core.backend registry"):
        toploc.ivf_plain_batch(ivf_index, q0[None], nprobe=NPROBE, k=K)
    conv = jnp.asarray(small_corpus.conversations[0])
    with pytest.warns(DeprecationWarning, match="ivf_conversation"):
        toploc.ivf_conversation(ivf_index, conv, h=H, nprobe=NPROBE, k=K)


# ---------------------------------------------- sequential bit-identity

@pytest.mark.parametrize("alpha", [-1.0, ALPHA])
def test_ivf_registry_matches_legacy_sequential(ivf_index, small_corpus,
                                                alpha):
    conv = jnp.asarray(small_corpus.conversations[0])
    bk = B.IVFBackend(h=H, nprobe=NPROBE, alpha=alpha)
    ref = _legacy(toploc.ivf_start, ivf_index, conv[0], h=H,
                  nprobe=NPROBE, k=K)
    got = toploc.start(bk, ivf_index, conv[0], k=K)
    _tree_equal(ref, got, "start")
    sess = got[2]
    for t in range(1, conv.shape[0]):
        ref = _legacy(toploc.ivf_step, ivf_index, sess, conv[t],
                      nprobe=NPROBE, k=K, alpha=alpha)
        got = toploc.step(bk, ivf_index, sess, conv[t], k=K)
        _tree_equal(ref, got, ("step", t))
        sess = got[2]


def test_ivf_pq_registry_matches_legacy_sequential(ivf_pq_index,
                                                   small_corpus):
    conv = jnp.asarray(small_corpus.conversations[1])
    bk = B.IVFPQBackend(h=H, nprobe=NPROBE, alpha=ALPHA, rerank=RR)
    ref = _legacy(toploc.ivf_pq_start, ivf_pq_index, conv[0], h=H,
                  nprobe=NPROBE, k=K, rerank=RR)
    got = toploc.start(bk, ivf_pq_index, conv[0], k=K)
    _tree_equal(ref, got, "pq start")
    sess = got[2]
    for t in range(1, conv.shape[0]):
        ref = _legacy(toploc.ivf_pq_step, ivf_pq_index, sess, conv[t],
                      nprobe=NPROBE, k=K, alpha=ALPHA, rerank=RR)
        got = toploc.step(bk, ivf_pq_index, sess, conv[t], k=K)
        _tree_equal(ref, got, ("pq step", t))
        sess = got[2]


def test_hnsw_registry_matches_legacy_sequential(hnsw_index, small_corpus):
    conv = jnp.asarray(small_corpus.conversations[2])
    bk = B.HNSWBackend(ef=EF, up=UP)
    ref = _legacy(toploc.hnsw_start, hnsw_index, conv[0], ef=EF, k=K,
                  up=UP)
    got = toploc.start(bk, hnsw_index, conv[0], k=K)
    _tree_equal(ref, got, "hnsw start")
    sess = got[2]
    for t in range(1, conv.shape[0]):
        ref = _legacy(toploc.hnsw_step, hnsw_index, sess, conv[t], ef=EF,
                      k=K)
        got = toploc.step(bk, hnsw_index, sess, conv[t], k=K)
        _tree_equal(ref, got, ("hnsw step", t))
        sess = got[2]


# ------------------------------------------------- batched bit-identity

def test_ivf_registry_matches_legacy_batched(ivf_index, small_corpus):
    q0 = jnp.asarray(small_corpus.conversations[:BATCH, 0])
    q1 = jnp.asarray(small_corpus.conversations[:BATCH, 1])
    bk = B.IVFBackend(h=H, nprobe=NPROBE, alpha=ALPHA)
    ref = _legacy(toploc.ivf_start_batch, ivf_index, q0, h=H,
                  nprobe=NPROBE, k=K)
    got = toploc.start_batch(bk, ivf_index, q0, k=K)
    _tree_equal(ref, got, "start_batch")
    sess = got[2]
    first = jnp.asarray([True, False, False, True])
    ref = _legacy(toploc.ivf_step_batch, ivf_index, sess, q1,
                  nprobe=NPROBE, k=K, alpha=ALPHA, is_first=first)
    got = toploc.step_batch(bk, ivf_index, sess, q1, k=K, is_first=first)
    _tree_equal(ref, got, "step_batch")
    ref = _legacy(toploc.ivf_plain_batch, ivf_index, q0, nprobe=NPROBE,
                  k=K)
    got = toploc.plain_batch(B.IVFBackend(nprobe=NPROBE), ivf_index, q0,
                             k=K)
    _tree_equal(ref, got, "plain_batch")


def test_ivf_pq_registry_matches_legacy_batched(ivf_pq_index,
                                                small_corpus):
    q0 = jnp.asarray(small_corpus.conversations[:BATCH, 0])
    q1 = jnp.asarray(small_corpus.conversations[:BATCH, 1])
    bk = B.IVFPQBackend(h=H, nprobe=NPROBE, alpha=ALPHA, rerank=RR)
    ref = _legacy(toploc.ivf_pq_start_batch, ivf_pq_index, q0, h=H,
                  nprobe=NPROBE, k=K, rerank=RR)
    got = toploc.start_batch(bk, ivf_pq_index, q0, k=K)
    _tree_equal(ref, got, "pq start_batch")
    sess = got[2]
    ref = _legacy(toploc.ivf_pq_step_batch, ivf_pq_index, sess, q1,
                  nprobe=NPROBE, k=K, alpha=ALPHA, rerank=RR)
    got = toploc.step_batch(bk, ivf_pq_index, sess, q1, k=K)
    _tree_equal(ref, got, "pq step_batch")
    ref = _legacy(toploc.ivf_pq_plain_batch, ivf_pq_index, q0,
                  nprobe=NPROBE, k=K, rerank=RR)
    got = toploc.plain_batch(B.IVFPQBackend(nprobe=NPROBE, rerank=RR),
                             ivf_pq_index, q0, k=K)
    _tree_equal(ref, got, "pq plain_batch")


def test_hnsw_registry_matches_legacy_batched(hnsw_index, small_corpus):
    q0 = jnp.asarray(small_corpus.conversations[:BATCH, 0])
    q1 = jnp.asarray(small_corpus.conversations[:BATCH, 1])
    bk = B.HNSWBackend(ef=EF, up=UP)
    ref = _legacy(toploc.hnsw_start_batch, hnsw_index, q0, ef=EF, k=K,
                  up=UP)
    got = toploc.start_batch(bk, hnsw_index, q0, k=K)
    _tree_equal(ref, got, "hnsw start_batch")
    sess = got[2]
    first = jnp.asarray([False, True, True, False])
    ref = _legacy(toploc.hnsw_step_batch, hnsw_index, sess, q1, ef=EF,
                  k=K, up=UP, is_first=first)
    got = toploc.step_batch(bk, hnsw_index, sess, q1, k=K, is_first=first)
    _tree_equal(ref, got, "hnsw step_batch")
    ref = _legacy(toploc.hnsw_plain_batch, hnsw_index, q0, ef=EF, k=K)
    got = toploc.plain_batch(B.HNSWBackend(ef=EF), hnsw_index, q0, k=K)
    _tree_equal(ref, got, "hnsw plain_batch")


# -------------------------------------------- conversation bit-identity

@pytest.mark.parametrize("mode", ["toploc", "plain"])
def test_ivf_conversation_matches_legacy(ivf_index, small_corpus, mode):
    conv = jnp.asarray(small_corpus.conversations[0])
    bk = B.IVFBackend(h=H, nprobe=NPROBE, alpha=ALPHA)
    ref = _legacy(toploc.ivf_conversation, ivf_index, conv, h=H,
                  nprobe=NPROBE, k=K, alpha=ALPHA, mode=mode)
    got = toploc.conversation(bk, ivf_index, conv, k=K, mode=mode)
    _tree_equal(ref, got, mode)


@pytest.mark.parametrize("mode", ["toploc", "plain"])
def test_ivf_pq_conversation_matches_legacy(ivf_pq_index, small_corpus,
                                            mode):
    conv = jnp.asarray(small_corpus.conversations[1])
    bk = B.IVFPQBackend(h=H, nprobe=NPROBE, alpha=ALPHA, rerank=RR)
    ref = _legacy(toploc.ivf_pq_conversation, ivf_pq_index, conv, h=H,
                  nprobe=NPROBE, k=K, alpha=ALPHA, rerank=RR, mode=mode)
    got = toploc.conversation(bk, ivf_pq_index, conv, k=K, mode=mode)
    _tree_equal(ref, got, mode)


@pytest.mark.parametrize("mode", ["toploc", "plain", "adaptive"])
def test_hnsw_conversation_matches_legacy(hnsw_index, small_corpus, mode):
    conv = jnp.asarray(small_corpus.conversations[2])
    bk = B.HNSWBackend(ef=EF, up=UP, adaptive=mode == "adaptive")
    ref = _legacy(toploc.hnsw_conversation, hnsw_index, conv, ef=EF, k=K,
                  up=UP, mode=mode)
    got = toploc.conversation(bk, hnsw_index, conv, k=K,
                              mode="plain" if mode == "plain" else
                              "toploc")
    _tree_equal(ref, got, mode)


# ------------------------------------------------------ exact + shapes

def test_exact_backend_plain(small_corpus):
    docs = jnp.asarray(small_corpus.doc_vecs)
    q = jnp.asarray(small_corpus.conversations[0, 0])
    bk = B.ExactBackend()
    from repro.core import ivf as _ivf
    ev, ei = _ivf.exact_search(docs, q[None], K)
    v, i, st = toploc.plain(bk, docs, q, k=K)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(ev[0]))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ei[0]))
    assert int(st.centroid_dists) == 0 and int(st.i0) == -1
    assert not bk.stateful and bk.session_template(docs) is None


def test_session_templates_match_store_layouts(ivf_index, hnsw_index):
    t = B.IVFBackend(h=H, nprobe=NPROBE).session_template(ivf_index)
    assert t.cache_ids.shape == (H,)
    assert t.cache_vecs.shape == (H, ivf_index.d)
    assert t.anchor_sel.shape == (NPROBE,)
    t = B.HNSWBackend().session_template(hnsw_index)
    assert t.entry_point.shape == () and t.turn.shape == ()


def test_corpus_vectors_resolution(ivf_index, ivf_pq_index, hnsw_index,
                                   small_corpus):
    docs = jnp.asarray(small_corpus.doc_vecs)
    assert B.IVFBackend().corpus_vectors(ivf_index) is None
    assert B.IVFPQBackend().corpus_vectors(ivf_pq_index) is \
        ivf_pq_index.doc_vecs
    assert B.HNSWBackend().corpus_vectors(hnsw_index) is \
        hnsw_index.vectors
    assert B.ExactBackend().corpus_vectors(docs) is docs
