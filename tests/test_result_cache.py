"""Session-level historical-embedding result cache (Frieder et al.).

Acceptance contract (PR-5): the cache at ``threshold <= 0`` is exactly
bit-identical to a cache-absent engine (scores, ids, records); enabled,
it answers cosine-close turns from cached documents without touching
the backend, keeps sequential and batched engines bit-identical to each
other, reports hit/miss counters, and can never leak entries across
sessions (end_conversation / slot eviction invalidate).
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import toploc
from repro.serving import (BatchedConversationalSearchEngine,
                           ConversationalSearchEngine, ResultCache,
                           ServingConfig)
from repro.serving import result_cache as RC

K, H, NPROBE, ALPHA = 10, 16, 4, 0.3
THRESH = 0.6          # hits real turns on the small_corpus workload


def _run_engine(eng, wl, n_conv=4, n_turns=4):
    out = []
    for c in range(n_conv):
        for t in range(n_turns):
            v, i = eng.query(f"c{c}", jnp.asarray(wl.conversations[c, t]))
            out.append((np.asarray(v), np.asarray(i)))
    return out


def _cfg(**kw):
    base = dict(backend="ivf", strategy="toploc+", nprobe=NPROBE, h=H,
                alpha=ALPHA, k=K)
    base.update(kw)
    return ServingConfig(**base)


# ------------------------------------------------- disabled == absent

@pytest.mark.parametrize("threshold", [0.0, -1.0])
def test_cache_off_equals_cache_absent(small_corpus, ivf_index, threshold):
    """threshold <= 0 must reproduce the uncached engine bit for bit."""
    wl = small_corpus
    docs = jnp.asarray(wl.doc_vecs)
    ref = _run_engine(ConversationalSearchEngine(
        _cfg(), ivf_index=ivf_index, doc_vecs=docs), wl)
    got_eng = ConversationalSearchEngine(
        _cfg(cache_threshold=threshold, cache_depth=32),
        ivf_index=ivf_index, doc_vecs=docs)
    got = _run_engine(got_eng, wl)
    for (rv, ri), (gv, gi) in zip(ref, got):
        np.testing.assert_array_equal(rv, gv)
        np.testing.assert_array_equal(ri, gi)
    assert got_eng._cache is None
    assert got_eng.cache_stats() == {}
    assert not any(r.cache_hit for r in got_eng.records)


def test_cache_off_equals_cache_absent_batched(small_corpus, ivf_index):
    wl = small_corpus
    ref = _run_engine(BatchedConversationalSearchEngine(
        _cfg(), ivf_index=ivf_index, max_batch=4, max_wait_s=1e-4), wl)
    got = _run_engine(BatchedConversationalSearchEngine(
        _cfg(cache_threshold=0.0), ivf_index=ivf_index, max_batch=4,
        max_wait_s=1e-4), wl)
    for (rv, ri), (gv, gi) in zip(ref, got):
        np.testing.assert_array_equal(rv, gv)
        np.testing.assert_array_equal(ri, gi)


# -------------------------------------------------------- hit behaviour

def test_cache_hits_skip_backend_and_report(small_corpus, ivf_index):
    """An identical repeated query is a guaranteed hit: same docs back,
    zero backend work in the record, counters advance."""
    wl = small_corpus
    docs = jnp.asarray(wl.doc_vecs)
    eng = ConversationalSearchEngine(
        _cfg(cache_threshold=0.99), ivf_index=ivf_index, doc_vecs=docs)
    q = jnp.asarray(wl.conversations[0, 0])
    v0, i0 = eng.query("c", q)
    v1, i1 = eng.query("c", q)              # cos(q, q) = 1 >= 0.99
    np.testing.assert_array_equal(i0, i1)
    np.testing.assert_allclose(v0, v1, rtol=1e-6)
    assert eng.records[0].cache_hit is False
    assert eng.records[1].cache_hit is True
    assert eng.records[1].centroid_dists == 0
    assert eng.records[1].list_dists == 0
    assert eng.cache_stats() == {"hits": 1, "misses": 1, "hit_rate": 0.5}
    assert eng.summary()["cache_hit_rate"] == 0.5
    # the session never stepped on the hit
    assert int(eng.sessions["c"].turn) == 1


def test_cache_miss_below_threshold(small_corpus, ivf_index):
    """A far-off query must fall through to the backend."""
    wl = small_corpus
    docs = jnp.asarray(wl.doc_vecs)
    eng = ConversationalSearchEngine(
        _cfg(cache_threshold=0.9), ivf_index=ivf_index, doc_vecs=docs)
    q0 = jnp.asarray(wl.conversations[0, 0])
    eng.query("c", q0)
    far = jnp.asarray(-np.asarray(q0))      # cosine -1
    eng.query("c", far)
    assert eng.cache_stats()["hits"] == 0
    assert not eng.records[1].cache_hit
    assert eng.records[1].centroid_dists > 0


def test_hit_rescoring_orders_by_new_query(small_corpus, ivf_index):
    """On a hit with a corpus, cached docs are re-scored under the NEW
    query — scores are exact dots of the returned docs."""
    wl = small_corpus
    docs = jnp.asarray(wl.doc_vecs)
    eng = ConversationalSearchEngine(
        _cfg(cache_threshold=0.5, cache_depth=32), ivf_index=ivf_index,
        doc_vecs=docs)
    q0 = jnp.asarray(wl.conversations[0, 0])
    q1 = jnp.asarray(wl.conversations[0, 1])
    eng.query("c", q0)
    v, i = eng.query("c", q1)
    if eng.records[1].cache_hit:            # threshold met on this seed
        exact = np.asarray(docs)[i] @ np.asarray(q1)
        np.testing.assert_allclose(v, exact, rtol=1e-5, atol=1e-6)
        assert np.all(np.diff(v) <= 1e-6)   # descending under q1


def test_cache_depth_over_fetches_and_serves_topk(small_corpus, ivf_index):
    wl = small_corpus
    docs = jnp.asarray(wl.doc_vecs)
    eng = ConversationalSearchEngine(
        _cfg(cache_threshold=0.99, cache_depth=32), ivf_index=ivf_index,
        doc_vecs=docs)
    q = jnp.asarray(wl.conversations[0, 0])
    v, i = eng.query("c", q)
    assert v.shape == (K,) and i.shape == (K,)
    entry = eng._cache._entries["c"]
    assert entry.doc_ids.shape == (32,)
    assert entry.doc_vecs.shape == (32, wl.doc_vecs.shape[1])


# ------------------------------------------- sequential == batched

@pytest.mark.parametrize("depth", [0, 32])
def test_cache_sequential_equals_batched(small_corpus, ivf_index, depth):
    """With the cache ENABLED and hitting, both engines stay
    bit-identical — hit rows in a wave keep their pre-step session and
    zeroed counters exactly like the sequential skip."""
    wl = small_corpus
    docs = jnp.asarray(wl.doc_vecs)
    cfg = _cfg(cache_threshold=THRESH, cache_depth=depth)
    seq = ConversationalSearchEngine(cfg, ivf_index=ivf_index,
                                     doc_vecs=docs)
    bat = BatchedConversationalSearchEngine(
        cfg, ivf_index=ivf_index, doc_vecs=docs, max_batch=4,
        max_wait_s=1e-4)
    for t in range(4):
        futs = []
        for c in range(4):
            qv = jnp.asarray(wl.conversations[c, t])
            sv, si = seq.query(f"c{c}", qv)
            futs.append((sv, si, bat.submit(f"c{c}", qv)))
        bat.drain()
        for sv, si, fut in futs:
            bv, bi = fut.result(timeout=5)
            np.testing.assert_array_equal(sv, bv)
            np.testing.assert_array_equal(si, bi)
    assert seq.cache_stats() == bat.cache_stats()
    assert seq.cache_stats()["hits"] > 0      # the test exercised hits
    def key(recs):
        return sorted((r.conv_id, r.turn, r.centroid_dists, r.list_dists,
                       r.code_dists, r.refreshed, r.i0, r.cache_hit)
                      for r in recs)
    assert key(seq.records) == key(bat.records)


# ------------------------------------------------- isolation / lifetime

def test_end_conversation_invalidates_entry(small_corpus, ivf_index):
    wl = small_corpus
    docs = jnp.asarray(wl.doc_vecs)
    eng = ConversationalSearchEngine(
        _cfg(cache_threshold=0.99), ivf_index=ivf_index, doc_vecs=docs)
    q = jnp.asarray(wl.conversations[0, 0])
    eng.query("c", q)
    eng.end_conversation("c")
    eng.query("c", q)                        # same query, fresh session
    assert eng.cache_stats()["hits"] == 0    # no stale hit
    assert not eng.records[-1].cache_hit


def test_slot_eviction_wipes_cache_row(small_corpus, ivf_index):
    """LRU-evicting a session slot must also clear its cache row: the
    slot's next conversation can never hit another user's entry, and the
    evicted conversation re-misses on return."""
    wl = small_corpus
    docs = jnp.asarray(wl.doc_vecs)
    bat = BatchedConversationalSearchEngine(
        _cfg(cache_threshold=0.99), ivf_index=ivf_index, doc_vecs=docs,
        n_slots=1, max_batch=1, max_wait_s=1e-4)
    qa = jnp.asarray(wl.conversations[0, 0])
    bat.query("a", qa)
    slot = bat.store.lookup("a")
    entry = bat._cache.gather([slot])
    assert bool(np.asarray(entry.valid)[0])
    bat.query("b", jnp.asarray(wl.conversations[1, 0]))   # evicts 'a'
    # 'a' repeats its exact query: entry is gone → miss, not a stale hit
    bat.query("a", qa)
    assert bat.cache_stats()["hits"] == 0
    assert not any(r.cache_hit for r in bat.records)


def test_cache_disabled_for_plain_and_stateless(small_corpus, ivf_index):
    """The cache is session-level state: plain strategy and stateless
    backends run uncached even with a threshold set."""
    wl = small_corpus
    docs = jnp.asarray(wl.doc_vecs)
    eng = ConversationalSearchEngine(
        _cfg(strategy="plain", cache_threshold=0.9), ivf_index=ivf_index,
        doc_vecs=docs)
    assert eng._cache is None
    eng = ConversationalSearchEngine(
        ServingConfig(backend="exact", k=K, cache_threshold=0.9),
        doc_vecs=docs)
    assert eng._cache is None


def test_static_mode_without_corpus_replays_ranking(small_corpus,
                                                    hnsw_index, ivf_index):
    """IVF keeps no flat corpus and none was passed: hits replay the
    cached ranking instead of re-scoring."""
    wl = small_corpus
    eng = ConversationalSearchEngine(
        _cfg(cache_threshold=0.99), ivf_index=ivf_index)
    assert eng._cache is not None and not eng._cache.rescore
    q = jnp.asarray(wl.conversations[0, 0])
    v0, i0 = eng.query("c", q)
    v1, i1 = eng.query("c", q)
    np.testing.assert_array_equal(i0, i1)
    np.testing.assert_array_equal(v0, v1)
    assert eng.records[1].cache_hit
    # HNSW auto-resolves its own corpus → rescoring on
    eng = ConversationalSearchEngine(
        ServingConfig(backend="hnsw", strategy="toploc", ef_search=16,
                      k=5, cache_threshold=0.99), hnsw_index=hnsw_index)
    assert eng._cache is not None and eng._cache.rescore


# ----------------------------------------------------------- unit level

def test_probe_requires_valid_entry():
    d, k = 8, 4
    entries = jax.tree.map(lambda a: a[None],
                           RC.entry_template(d, k))
    q = jnp.ones((1, d), jnp.float32)
    hit, v, ids = RC.probe(entries, q, out_k=k, threshold=0.0,
                           rescore=True)
    assert not bool(hit[0])                  # invalid entry never hits
    assert ids.shape == (1, k)


def test_probe_threshold_boundary():
    d, k = 4, 2
    q0 = jnp.asarray([1.0, 0.0, 0.0, 0.0])
    entry = RC.CacheEntry(
        q_vec=q0, doc_ids=jnp.asarray([3, 7], jnp.int32),
        doc_scores=jnp.asarray([2.0, 1.0]),
        doc_vecs=jnp.zeros((k, d)), valid=jnp.asarray(True))
    entries = jax.tree.map(lambda a: a[None], entry)
    # cos = 1 exactly at the anchor query
    hit, v, ids = RC.probe(entries, q0[None], out_k=k, threshold=1.0,
                           rescore=False)
    assert bool(hit[0])
    np.testing.assert_array_equal(np.asarray(ids[0]), [3, 7])
    # orthogonal query: cos 0 < 0.5
    q_orth = jnp.asarray([[0.0, 1.0, 0.0, 0.0]])
    hit, _, _ = RC.probe(entries, q_orth, out_k=k, threshold=0.5,
                         rescore=False)
    assert not bool(hit[0])


def test_result_cache_depth_floor():
    cache = ResultCache(d=8, k=10, threshold=0.5, depth=4)
    assert cache.depth == 10                 # depth never below k


def test_cache_depth_clamped_to_hnsw_beam(small_corpus, hnsw_index):
    """cache_depth beyond ef must clamp to the beam width instead of
    crashing the follow-up search (top_k over an ef-wide pool)."""
    wl = small_corpus
    eng = ConversationalSearchEngine(
        ServingConfig(backend="hnsw", strategy="toploc", ef_search=16,
                      up=2, k=5, cache_threshold=0.5, cache_depth=64),
        hnsw_index=hnsw_index)
    assert eng._cache.depth == 16            # ef, not 64
    for t in range(3):                       # miss+hit turns both survive
        v, i = eng.query("c", jnp.asarray(wl.conversations[0, t]))
        assert v.shape == (5,) and i.shape == (5,)


def test_cache_depth_clamped_to_pq_rerank(small_corpus, ivf_pq_index):
    """cache_depth beyond the IVF-PQ re-rank depth would widen the exact
    re-rank pool on miss turns (different candidates, inflated
    counters); it must clamp to rerank so misses serve exactly the
    uncached top-k."""
    wl = small_corpus
    cfg = ServingConfig(backend="ivf_pq", strategy="toploc+",
                        nprobe=NPROBE, h=H, alpha=ALPHA, k=K, rerank=32)
    ref = ConversationalSearchEngine(cfg, ivf_pq_index=ivf_pq_index)
    cached = ConversationalSearchEngine(
        ServingConfig(**{**cfg.__dict__, "cache_threshold": 0.5,
                         "cache_depth": 128}),
        ivf_pq_index=ivf_pq_index)
    assert cached._cache.depth == 32         # rerank, not 128
    for t in range(4):
        qv = jnp.asarray(wl.conversations[0, t])
        rv, ri = ref.query("c", qv)
        cv, ci = cached.query("c", qv)
        if cached.records[-1].cache_hit:
            break                             # sessions legitimately fork
        # miss turns serve exactly the uncached top-k, same counters
        np.testing.assert_array_equal(rv, cv)
        np.testing.assert_array_equal(ri, ci)
        assert (cached.records[-1].list_dists
                == ref.records[-1].list_dists)


# --------------------------------------------- corpus mutation safety

def test_cache_hit_never_serves_deleted_doc_sequential(small_corpus,
                                                       ivf_index):
    """delete_documents must flush every cache entry holding the dead
    id: the near-dup follow-up that would have been a hit re-runs the
    backend (tombstone-masked) instead of replaying the stale entry."""
    wl = small_corpus
    eng = ConversationalSearchEngine(
        _cfg(cache_threshold=0.95, segment_cap=4),
        ivf_index=ivf_index, doc_vecs=jnp.asarray(wl.doc_vecs))
    q = jnp.asarray(wl.conversations[0, 0])
    eng.query("c", q)
    _, i_hit = eng.query("c", q)            # verbatim repeat: sure hit
    assert eng.records[-1].cache_hit
    victim = int(np.asarray(i_hit)[0])
    eng.delete_documents([victim])
    assert eng.corpus_epoch == 1
    _, i2 = eng.query("c", q)
    assert not eng.records[-1].cache_hit    # entry was invalidated
    assert victim not in np.asarray(i2)
    # the re-run repopulated the cache without the dead doc
    _, i3 = eng.query("c", q)
    assert eng.records[-1].cache_hit
    assert victim not in np.asarray(i3)


def test_cache_hit_never_serves_deleted_doc_batched(small_corpus,
                                                    ivf_index):
    """Same contract through the batched engine's slab-mode cache: the
    tombstone sweep walks the device slab's doc_ids and clears hit rows
    via the (batched) SessionStore.clear."""
    wl = small_corpus
    eng = BatchedConversationalSearchEngine(
        _cfg(cache_threshold=0.95, segment_cap=4),
        ivf_index=ivf_index, doc_vecs=jnp.asarray(wl.doc_vecs),
        max_batch=4, max_wait_s=1e-4)
    q = jnp.asarray(wl.conversations[0, 0])
    eng.query("c", q)
    _, i_hit = eng.query("c", q)
    assert eng.records[-1].cache_hit
    victim = int(np.asarray(i_hit)[0])
    eng.delete_documents([victim])
    _, i2 = eng.query("c", q)
    assert not eng.records[-1].cache_hit
    assert victim not in np.asarray(i2)
    _, i3 = eng.query("c", q)
    assert eng.records[-1].cache_hit
    assert victim not in np.asarray(i3)


def test_adds_leave_cache_entries_valid(small_corpus, ivf_index):
    """Ingest never invalidates: an existing entry's docs are all still
    live, so the hit path stays warm (new docs become visible to cached
    conversations at their next miss — documented staleness)."""
    wl = small_corpus
    eng = ConversationalSearchEngine(
        _cfg(cache_threshold=0.95, segment_cap=4),
        ivf_index=ivf_index, doc_vecs=jnp.asarray(wl.doc_vecs))
    q = jnp.asarray(wl.conversations[0, 0])
    eng.query("c", q)
    eng.add_documents(wl.doc_vecs[:2] * 0.7)
    _, _ = eng.query("c", q)
    assert eng.records[-1].cache_hit
