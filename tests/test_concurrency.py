"""Deterministic-schedule race tests for the serving layer.

Two layers under test:

1. The sanitizer itself (``repro.analysis.tsan`` + ``schedules``): a
   seeded data race / lockset break / lock-order inversion must be
   detected, a clean program must produce zero reports, and the same
   seed must replay the same interleaving (trace determinism).
2. The serving stack under the sanitizer: every seed of the fixed
   matrix ``schedules.SEEDS`` replays the overlapped-wave engine,
   delete-racing-wave, and router-mutation scenarios with per-session
   **bit-identity** to the sequential engine and **zero** concurrency
   violations — the dynamic counterpart of the static lock-order /
   guarded-fields passes.

These tests are single-device and self-contained (no pump thread is
left running); CI runs them as the dedicated ``concurrency`` job.
"""
import threading

import numpy as np
import pytest
import jax.numpy as jnp

from repro.analysis import schedules, tsan
from repro.concurrency import guarded_by
from repro.serving import (BatchedConversationalSearchEngine,
                           ConversationalSearchEngine,
                           ReplicatedSearchEngine, ServingConfig,
                           SessionStore)
from repro.serving.result_cache import ResultCache
from repro.serving.scheduler import HedgedExecutor, MicroBatcher

K, H, NPROBE = 10, 16, 4
B, T = 4, 3                   # conversations x turns per scenario

#: serving-layer classes under guarded-field interception
WATCH = (MicroBatcher, SessionStore, ResultCache, HedgedExecutor,
         ReplicatedSearchEngine)


def _cfg(**kw):
    return ServingConfig(backend="ivf", strategy="toploc+",
                         nprobe=NPROBE, h=H, alpha=0.3, k=K,
                         cache_threshold=0.7, **kw)


# ---------------------------------------------------------------------------
# the sanitizer itself
# ---------------------------------------------------------------------------


@guarded_by("_lock", "n")
class _Counter:
    def __init__(self):
        self._lock = threading.Lock()
        self.n = 0

    def bump_unlocked(self):
        self.n += 1

    def bump_locked(self):
        with self._lock:
            self.n += 1


def test_tsan_detects_seeded_race_and_lockset_break():
    rt = tsan.Runtime(schedule=schedules.ScheduleExplorer(3))
    with tsan.instrument(rt):
        c = _Counter()
        with tsan.watch(rt, _Counter):
            schedules.run_threads([c.bump_unlocked, c.bump_unlocked])
    kinds = {r.kind for r in rt.reports}
    assert "race" in kinds and "lockset" in kinds, rt.reports


def test_tsan_clean_program_produces_no_reports():
    rt = tsan.Runtime(schedule=schedules.ScheduleExplorer(3))
    with tsan.instrument(rt):
        c = _Counter()
        with tsan.watch(rt, _Counter):
            schedules.run_threads([c.bump_locked, c.bump_locked])
    tsan.assert_clean(rt)
    with c._lock:
        assert c.n == 2


def test_tsan_reports_lock_order_inversion():
    class AB:
        def __init__(self):
            self.a = threading.Lock()
            self.b = threading.Lock()

        def ab(self):
            with self.a:
                with self.b:
                    pass

        def ba(self):
            with self.b:
                with self.a:
                    pass

    rt = tsan.Runtime()
    with tsan.instrument(rt):
        x = AB()
        x.ab()
        x.ba()
    assert any(r.kind == "lock-order" for r in rt.reports), rt.reports


def test_tsan_assert_clean_raises_listing_reports():
    rt = tsan.Runtime(schedule=schedules.ScheduleExplorer(0))
    with tsan.instrument(rt):
        c = _Counter()
        with tsan.watch(rt, _Counter):
            schedules.run_threads([c.bump_unlocked, c.bump_unlocked])
    with pytest.raises(AssertionError, match="concurrency violation"):
        tsan.assert_clean(rt)


def test_seed_matrix_has_at_least_20_distinct_schedules():
    assert len(set(schedules.SEEDS)) >= 20


def test_schedule_decision_is_pure_and_seed_sensitive():
    e1 = schedules.ScheduleExplorer(7)
    e2 = schedules.ScheduleExplorer(7)
    e3 = schedules.ScheduleExplorer(8)
    probes = [("client-0", n, "lock-acquire") for n in range(64)]
    d1 = [e1.decision(*p) for p in probes]
    assert d1 == [e2.decision(*p) for p in probes]
    assert d1 != [e3.decision(*p) for p in probes]


def test_schedule_replay_same_seed_same_interleaving():
    def scen(rt):
        c = _Counter()
        schedules.run_threads([c.bump_locked] * 3,
                              names=["t-0", "t-1", "t-2"])
        with c._lock:
            return c.n

    r1, e1, _ = schedules.replay(7, scen, watch_classes=[_Counter])
    r2, e2, _ = schedules.replay(7, scen, watch_classes=[_Counter])
    named1 = {k: v for k, v in e1.traces.items() if k.startswith("t-")}
    named2 = {k: v for k, v in e2.traces.items() if k.startswith("t-")}
    assert r1 == r2 == 3
    # same seed -> bit-identical per-thread decision traces
    assert named1 == named2 and len(named1) == 3
    # a different seed steers a different interleaving
    _, e3, _ = schedules.replay(8, scen, watch_classes=[_Counter])
    named3 = {k: v for k, v in e3.traces.items() if k.startswith("t-")}
    assert named1 != named3


# ---------------------------------------------------------------------------
# scenario A — overlapped-wave engine vs sequential oracle, all seeds
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def convs(small_corpus):
    return jnp.asarray(small_corpus.conversations[:B, :T])


@pytest.fixture(scope="module")
def oracle(ivf_index, convs):
    """Per-conversation (scores, ids) rows from the sequential engine."""
    eng = ConversationalSearchEngine(_cfg(), ivf_index=ivf_index)
    out = {}
    for b in range(B):
        rows = []
        for t in range(T):
            v, i = eng.query(f"c{b}", convs[b, t])
            rows.append((np.asarray(v), np.asarray(i)))
        out[f"c{b}"] = rows
    return out


@pytest.mark.parametrize("seed", schedules.SEEDS)
def test_batched_engine_bit_identical_under_every_schedule(
        seed, ivf_index, convs, oracle):
    """B client threads drive B conversations through the overlapped
    continuous-batching engine under one seeded schedule; every turn
    must be bit-identical to the sequential oracle and the schedule
    must expose no data race / lockset break / lock-order inversion."""

    def scenario(rt):
        eng = BatchedConversationalSearchEngine(
            _cfg(), ivf_index=ivf_index, n_slots=8, max_batch=B,
            max_wait_s=1e-4)
        results = {f"c{b}": [] for b in range(B)}

        def client(b):
            cid = f"c{b}"
            for t in range(T):
                v, i = eng.query(cid, convs[b, t])
                results[cid].append((np.asarray(v), np.asarray(i)))

        schedules.run_threads(
            [lambda b=b: client(b) for b in range(B)],
            names=[f"client-{b}" for b in range(B)])
        eng.close()
        return results

    results, _, rt = schedules.replay(seed, scenario, watch_classes=WATCH)
    assert not rt.reports
    for cid, want in oracle.items():
        got = results[cid]
        assert len(got) == len(want)
        for t, ((wv, wi), (gv, gi)) in enumerate(zip(want, got)):
            np.testing.assert_array_equal(wi, gi, err_msg=f"{cid} turn {t}")
            np.testing.assert_array_equal(wv, gv, err_msg=f"{cid} turn {t}")


# ---------------------------------------------------------------------------
# scenario B — delete racing an in-flight wave, all seeds
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", schedules.SEEDS)
def test_delete_never_serves_tombstone_under_any_schedule(
        seed, ivf_index, small_corpus):
    """A ``delete_documents`` racing in-flight waves must never serve
    the tombstoned doc afterwards — from the backend *or* from a stale
    result-cache entry.  Turns whose submit started after the delete
    returned assert the doc is gone; earlier turns may legally still
    see it (they raced the delete)."""
    doc_vecs = jnp.asarray(small_corpus.doc_vecs)

    def scenario(rt):
        eng = BatchedConversationalSearchEngine(
            _cfg(segment_cap=64), ivf_index=ivf_index,
            doc_vecs=doc_vecs, n_slots=8, max_batch=4, max_wait_s=1e-4)
        # aim every query at one known-retrievable doc
        _, i = eng.query("probe", jnp.asarray(
            small_corpus.conversations[0, 0]))
        target = int(np.asarray(i)[0])
        q = doc_vecs[target]
        deleted = threading.Event()

        def client(name):
            for _ in range(4):
                pre = deleted.is_set()
                _, ids = eng.query(name, q)
                if pre:
                    assert target not in np.asarray(ids).tolist(), \
                        f"{name} served tombstoned doc {target}"

        def mutator():
            eng.delete_documents([target])
            deleted.set()

        schedules.run_threads(
            [lambda: client("cA"), lambda: client("cB"), mutator],
            names=["client-A", "client-B", "mutator"])
        eng.close()
        return target

    _, _, rt = schedules.replay(seed, scenario, watch_classes=WATCH)
    assert not rt.reports


# ---------------------------------------------------------------------------
# scenario C — router mutation (add/delete/compact) racing queries
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", schedules.SEEDS[:4])
def test_router_mutation_racing_queries_is_clean(
        seed, ivf_index, small_corpus):
    """add/delete/compact broadcast over a 2-replica router while
    pinned clients keep querying: no violation, every turn serves k
    results, and the replicas stay on one corpus epoch."""
    doc_vecs = jnp.asarray(small_corpus.doc_vecs)
    d = doc_vecs.shape[1]
    rng = np.random.default_rng(seed)
    new_rows = rng.standard_normal((3, d)).astype(np.float32)
    new_rows /= np.linalg.norm(new_rows, axis=1, keepdims=True)

    def scenario(rt):
        router = ReplicatedSearchEngine(
            _cfg(segment_cap=64), replicas=2, ivf_index=ivf_index,
            doc_vecs=doc_vecs, n_slots=8, max_batch=4, max_wait_s=1e-4)

        def client(b):
            cid = f"c{b}"
            for t in range(T):
                _, ids = router.query(
                    cid, jnp.asarray(small_corpus.conversations[b, t]))
                assert np.asarray(ids).shape == (K,)

        def mutator():
            ids = router.add_documents(new_rows)
            router.delete_documents([int(ids[0])])
            router.compact()

        schedules.run_threads(
            [lambda b=b: client(b) for b in range(3)] + [mutator],
            names=[f"client-{b}" for b in range(3)] + ["mutator"])
        epochs = [e.corpus_epoch for e in router.engines]
        router.close()
        return epochs

    epochs, _, rt = schedules.replay(seed, scenario, watch_classes=WATCH)
    assert not rt.reports
    assert len(set(epochs)) == 1 and epochs[0] == 3
