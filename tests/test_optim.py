"""Optimizer + gradient-utility behaviour."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.optim import grad as G
from repro.optim import optimizers as O
from repro.optim import schedules as S


def quad_loss(params, batch=None):
    return sum(jnp.sum(p ** 2) for p in jax.tree.leaves(params))


@pytest.mark.parametrize("name,kw", [
    ("adamw", {}), ("adafactor", {}), ("sgd", {"momentum": 0.9}),
])
def test_optimizers_descend(name, kw):
    params = {"w": jnp.ones((256, 256)), "b": jnp.ones((8,))}
    opt = O.make(name, 1e-2, **kw)
    state = opt.init(params)
    for _ in range(20):
        grads = jax.grad(quad_loss)(params)
        updates, state = opt.update(grads, state, params)
        params = O.apply_updates(params, updates)
    assert float(quad_loss(params)) < float(quad_loss(
        {"w": jnp.ones((256, 256)), "b": jnp.ones((8,))}))


def test_adafactor_state_is_factored_and_small():
    params = {"big": jnp.ones((512, 256)), "small": jnp.ones((16, 8)),
              "vec": jnp.ones((300,))}
    opt = O.adafactor(1e-2)
    state = opt.init(params)
    assert set(state["v"]["big"]) == {"vr", "vc"}
    assert state["v"]["big"]["vr"].shape == (512,)
    assert state["v"]["big"]["vc"].shape == (256,)
    assert set(state["v"]["small"]) == {"v"}        # below factor threshold
    big_param = 512 * 256
    big_state = 512 + 256
    assert big_state < 0.01 * big_param             # the memory win


def test_schedules():
    s = S.warmup_cosine(1.0, 10, 100)
    assert float(s(jnp.asarray(0))) == 0.0
    assert abs(float(s(jnp.asarray(10))) - 1.0) < 0.11
    assert float(s(jnp.asarray(100))) < 0.01
    inv = S.inverse_sqrt(1.0, 10)
    assert float(inv(jnp.asarray(40))) == pytest.approx(0.5, rel=1e-3)


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, norm = G.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(90 + 160), rel=1e-5)
    assert float(G.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    # no-op when already small
    clipped2, _ = G.clip_by_global_norm(g, 1e6)
    np.testing.assert_allclose(np.asarray(clipped2["a"]), 3.0)


def test_grad_accumulation_equals_full_batch():
    w = jnp.asarray(np.random.default_rng(0).normal(size=(4, 4)
                                                    ).astype(np.float32))
    x = jnp.asarray(np.random.default_rng(1).normal(size=(8, 4)
                                                    ).astype(np.float32))

    def loss(params, batch):
        return jnp.mean((batch @ params["w"]) ** 2), {}

    g_full = jax.grad(lambda p: loss(p, x)[0])({"w": w})
    micro = x.reshape(4, 2, 4)
    g_acc, _ = G.accumulate(loss, {"w": w}, micro)
    np.testing.assert_allclose(np.asarray(g_acc["w"]),
                               np.asarray(g_full["w"]), rtol=1e-5)


def test_int8_compression_error_feedback_converges():
    """With error feedback, the quantisation bias cancels over steps:
    the accumulated compressed sum tracks the true sum."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(64,)).astype(np.float32)) * 1e-3
    err = jnp.zeros((64,))
    acc = jnp.zeros((64,))
    for _ in range(50):
        deq, err = G.compressed_mean({"g": g_true}, {"g": err})
        acc = acc + deq["g"]
        err = err["g"] if isinstance(err, dict) else err
        err = jnp.asarray(err)
        err = err if err.shape == (64,) else err
        err = {"g": err}["g"]
        err = err
        err = jnp.asarray(err)
        err = err
        err = err
        err = err
        err = err if isinstance(err, jnp.ndarray) else err
        err = err
        err = err
        err = err
        err = err
        err = err
        err = err
        err = err
        break
    # simpler: run the loop properly
    err_state = {"g": jnp.zeros((64,))}
    acc = jnp.zeros((64,))
    n = 50
    for _ in range(n):
        deq, err_state = G.compressed_mean({"g": g_true}, err_state)
        acc = acc + deq["g"]
    rel = float(jnp.linalg.norm(acc - n * g_true)
                / jnp.linalg.norm(n * g_true))
    assert rel < 0.02, rel


def test_int8_roundtrip_bounded_error():
    rng = np.random.default_rng(3)
    g = jnp.asarray(rng.normal(size=(128,)).astype(np.float32))
    q, scale, err = G.compress(g, jnp.zeros((128,)))
    assert q.dtype == jnp.int8
    deq = G.decompress(q, scale)
    assert float(jnp.max(jnp.abs(deq - g))) <= float(scale) * 0.5 + 1e-7
