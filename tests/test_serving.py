"""Serving engine + scheduler behaviour."""
import time

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import ivf
from repro.serving.engine import ConversationalSearchEngine, ServingConfig
from repro.serving.scheduler import (HedgedExecutor, MicroBatcher, Request)


@pytest.fixture(scope="module")
def engine_setup(small_corpus_mod):
    wl = small_corpus_mod
    idx = ivf.build(jnp.asarray(wl.doc_vecs), p=32, iters=4,
                    key=jax.random.PRNGKey(0))
    return wl, idx


@pytest.fixture(scope="module")
def small_corpus_mod():
    from repro.data import synthetic as SY
    return SY.make_workload(SY.WorkloadConfig(
        n_docs=2000, d=32, n_topics=16, n_conversations=3,
        turns_per_conversation=5, seed=1))


def test_session_lifecycle(engine_setup):
    wl, idx = engine_setup
    eng = ConversationalSearchEngine(
        ServingConfig(backend="ivf", strategy="toploc", nprobe=4, h=8,
                      k=10), ivf_index=idx)
    for t in range(3):
        v, i = eng.query("c0", jnp.asarray(wl.conversations[0, t]))
        assert v.shape == (10,) and i.shape == (10,)
    assert "c0" in eng.sessions
    # turn 0 pays the full scan; later turns pay h
    assert eng.records[0].centroid_dists == idx.p
    assert eng.records[1].centroid_dists == 8
    eng.end_conversation("c0")
    assert "c0" not in eng.sessions


def test_strategies_work_ordering(engine_setup):
    """plain pays p per turn; toploc pays h << p after turn 0."""
    wl, idx = engine_setup
    work = {}
    for strat in ("plain", "toploc", "toploc+"):
        eng = ConversationalSearchEngine(
            ServingConfig(backend="ivf", strategy=strat, nprobe=4, h=8,
                          alpha=0.1, k=10), ivf_index=idx)
        for c in range(2):
            for t in range(5):
                eng.query(f"c{c}", jnp.asarray(wl.conversations[c, t]))
        work[strat] = eng.summary()["mean_centroid_dists"]
    assert work["toploc"] < work["plain"]
    assert work["toploc+"] < work["plain"]


def test_exact_backend(engine_setup):
    wl, idx = engine_setup
    eng = ConversationalSearchEngine(
        ServingConfig(backend="exact", k=5),
        doc_vecs=jnp.asarray(wl.doc_vecs))
    v, i = eng.query("c", jnp.asarray(wl.conversations[0, 0]))
    ev, ei = ivf.exact_search(jnp.asarray(wl.doc_vecs),
                              jnp.asarray(wl.conversations[0, :1]), 5)
    np.testing.assert_array_equal(i, np.asarray(ei[0]))


def test_micro_batcher_flushes():
    seen = []

    def process(reqs):
        seen.append(len(reqs))
        return [r.payload * 2 for r in reqs]

    mb = MicroBatcher(process, max_batch=4, max_wait_s=0.01)
    futs = [mb.submit(Request("c", i)) for i in range(6)]
    mb.flush_loop_once()
    mb.flush_loop_once()
    assert [f.result(timeout=1) for f in futs] == [0, 2, 4, 6, 8, 10]
    assert seen[0] == 4 and seen[1] == 2


def test_micro_batcher_propagates_errors():
    def process(reqs):
        raise RuntimeError("boom")

    mb = MicroBatcher(process, max_batch=2, max_wait_s=0.001)
    fut = mb.submit(Request("c", 1))
    mb.flush_loop_once()
    with pytest.raises(RuntimeError):
        fut.result(timeout=1)


def test_micro_batcher_pads_flushes_to_bucket():
    """Every flush reaching the process callback is padded to the next
    bucket with trailing PAD_ID requests — ragged sizes never hit the
    jitted device program."""
    seen = []

    def process(reqs):
        seen.append([r.conv_id for r in reqs])
        return [r.payload * 2 for r in reqs]

    mb = MicroBatcher(process, max_batch=8, max_wait_s=0.001,
                      buckets=(1, 2, 4, 8))
    futs = [mb.submit(Request(f"c{i}", i)) for i in range(3)]
    mb.flush_loop_once()
    assert [f.result(timeout=1) for f in futs] == [0, 2, 4]
    # raw 3 → dispatched 4: one trailing pad row
    assert mb.batch_sizes == [3] and mb.padded_sizes == [4]
    assert len(seen[0]) == 4
    assert seen[0][3] == MicroBatcher.PAD_ID
    assert seen[0][:3] == ["c0", "c1", "c2"]


def test_micro_batcher_jit_cache_stability():
    """Dispatched batch sizes stay inside the bucket table across ragged
    arrival patterns (the jit-recompile-per-size regression)."""
    lens = []
    mb = MicroBatcher(lambda reqs: [r.payload for r in reqs],
                      max_batch=8, max_wait_s=0.0, buckets=(1, 2, 4, 8))
    for n in (1, 3, 5, 2, 7, 6):
        futs = [mb.submit(Request("c", j)) for j in range(n)]
        mb.flush_loop_once()
        lens.append(n)
        for f in futs:
            f.result(timeout=1)
    assert mb.batch_sizes == lens
    assert set(mb.padded_sizes) <= {1, 2, 4, 8}
    assert mb.padded_sizes == [mb.bucket(n) for n in lens]


def test_hedged_executor_mitigates_straggler():
    def fast(x):
        return ("fast", x)

    def slow(x):
        time.sleep(0.25)
        return ("slow", x)

    # round-robin alternates; hedging should rescue calls landing on slow
    ex = HedgedExecutor([fast, slow], hedge_quantile=0.5, min_history=4,
                        hedge_floor_s=0.02)
    results = [ex.call(i) for i in range(12)]
    st = ex.stats()
    assert st["hedges_issued"] > 0
    assert st["hedges_won"] > 0
    # every call returned a correct payload
    assert all(r[1] == i for i, r in enumerate(results))
    # p99 stays well under the slow replica's latency x2
    assert st["p99_ms"] < 600


def test_hedged_executor_survives_failing_fast_replica():
    """A replica that fails *after* the hedge fired must not poison the
    call: the surviving replica's result is returned, and the rescue is
    not miscounted as a latency win (the hedge did not beat a pending
    primary — the primary completed, with an exception)."""
    def failing(x):
        time.sleep(0.05)
        raise RuntimeError("replica down")

    def slow_ok(x):
        time.sleep(0.12)
        return ("ok", x)

    ex = HedgedExecutor([failing, slow_ok], hedge_floor_s=0.01,
                        min_history=99)
    assert ex.call(7) == ("ok", 7)
    st = ex.stats()
    assert st["hedges_issued"] == 1
    assert st["hedges_won"] == 0


def test_hedged_executor_hedge_win_is_deterministic():
    """hedges_won counts exactly the hedges that strictly beat a
    still-pending primary; a successful primary always wins over a
    hedge that completed in the same wait wake-up."""
    def very_slow(x):
        time.sleep(0.3)
        return ("slow", x)

    def instant(x):
        return ("fast", x)

    ex = HedgedExecutor([very_slow, instant], hedge_floor_s=0.01,
                        min_history=99)
    assert ex.call(1) == ("fast", 1)       # hedge rescued the straggler
    assert ex.stats()["hedges_won"] == 1


def test_hedged_executor_raises_only_when_all_replicas_fail():
    def bad_a(x):
        time.sleep(0.03)
        raise ValueError("a")

    def bad_b(x):
        time.sleep(0.03)
        raise ValueError("b")

    ex = HedgedExecutor([bad_a, bad_b], hedge_floor_s=0.005,
                        min_history=99)
    with pytest.raises(ValueError, match="a"):   # primary's exception
        ex.call(0)
    assert ex.stats()["calls"] == 1


def test_hedged_executor_fails_over_on_fast_primary_failure():
    """A primary that fails *before* the hedge deadline triggers an
    immediate failover to the backup instead of raising with a healthy
    replica untried."""
    def instant_crash(x):
        raise ConnectionError("refused")

    def healthy(x):
        return ("ok", x)

    ex = HedgedExecutor([instant_crash, healthy], hedge_floor_s=0.05,
                        min_history=99)
    assert ex.call(3) == ("ok", 3)
    st = ex.stats()
    assert st["failovers"] == 1
    assert st["hedges_issued"] == 0 and st["hedges_won"] == 0


def test_micro_batcher_pads_drains_beyond_largest_bucket():
    """max_batch above the bucket table gets its own bucket inside the
    batcher itself, so an oversized drain still dispatches bucketed."""
    seen = []
    mb = MicroBatcher(lambda reqs: (seen.append(len(reqs)),
                                    [r.payload for r in reqs])[1],
                      max_batch=64, max_wait_s=0.0, buckets=(1, 2, 4, 8,
                                                            16, 32))
    assert mb.bucket(50) == 64
    futs = [mb.submit(Request("c", j)) for j in range(50)]
    mb.flush_loop_once()
    for f in futs:
        f.result(timeout=1)
    assert seen == [64]
    assert mb.batch_sizes == [50] and mb.padded_sizes == [64]


def test_hedged_executor_latency_history_is_bounded():
    """The adaptive-deadline history is a maxlen deque: _deadline() cost
    stays O(window) and reflects recent traffic, while the calls counter
    keeps the all-time total."""
    ex = HedgedExecutor([lambda x: x], lat_window=4, min_history=2,
                        hedge_floor_s=0.001)
    for i in range(10):
        assert ex.call(i) == i
    assert len(ex._lat) == 4
    assert ex.stats()["calls"] == 10
    assert ex._deadline() >= 0.001


def test_micro_batcher_empty_flush_is_a_noop():
    """An empty queue drains nothing: no callback, no recorded sizes,
    return value 0 — the serving loop can spin on flush_loop_once."""
    calls = []
    mb = MicroBatcher(lambda reqs: (calls.append(len(reqs)),
                                    [r.payload for r in reqs])[1],
                      max_batch=4, max_wait_s=0.0005)
    assert mb.flush_loop_once() == 0
    assert calls == []
    assert mb.batch_sizes == [] and mb.padded_sizes == []


def test_micro_batcher_exact_max_batch_needs_no_padding():
    """A drain of exactly max_batch sits on the bucket boundary: the
    dispatched batch is the raw batch — no pad requests at all."""
    seen_ids = []
    mb = MicroBatcher(lambda reqs: (seen_ids.append(
        [r.conv_id for r in reqs]), [r.payload for r in reqs])[1],
        max_batch=8, max_wait_s=0.0, buckets=(1, 2, 4, 8))
    futs = [mb.submit(Request(f"c{j}", j)) for j in range(8)]
    assert mb.flush_loop_once() == 8
    assert [f.result(timeout=1) for f in futs] == list(range(8))
    assert mb.batch_sizes == [8] and mb.padded_sizes == [8]
    assert MicroBatcher.PAD_ID not in seen_ids[0]


def test_hedged_executor_single_replica_never_hedges():
    """One replica = zero configured hedges: a slow call still returns
    (no backup to race), and nothing is counted as hedge or failover."""
    def slow(x):
        time.sleep(0.02)
        return x * 2

    ex = HedgedExecutor([slow], hedge_floor_s=0.001, min_history=2)
    assert [ex.call(i) for i in range(3)] == [0, 2, 4]
    assert ex.hedges_issued == 0 and ex.failovers == 0
    assert ex.hedges_won == 0


def test_hedged_executor_single_failing_replica_raises():
    """With no backup replica, the primary's exception must propagate
    instead of hanging or hedging."""
    def bad(x):
        raise RuntimeError("replica down")

    ex = HedgedExecutor([bad], hedge_floor_s=0.001)
    with pytest.raises(RuntimeError, match="replica down"):
        ex.call(1)
    assert ex.hedges_issued == 0 and ex.failovers == 0


def test_micro_batcher_stats_consistent_under_concurrent_flushers():
    """Two serving-loop threads hammering flush_loop_once while clients
    submit: the drain path is single-owner and the stats lists are
    guarded by the lock, so batch_sizes/padded_sizes stay zipped
    (len equal, every padded >= raw, all results correct)."""
    import threading

    mb = MicroBatcher(lambda reqs: [r.payload for r in reqs],
                      max_batch=8, max_wait_s=0.0, buckets=(1, 2, 4, 8))
    futs = []
    stop = threading.Event()

    def flusher():
        while not stop.is_set():
            mb.flush_loop_once()

    threads = [threading.Thread(target=flusher) for _ in range(2)]
    for t in threads:
        t.start()
    try:
        for j in range(300):
            futs.append((j, mb.submit(Request(f"c{j}", j))))
        assert [f.result(timeout=5) for _, f in futs] == list(range(300))
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=5)
    while mb.flush_loop_once():
        pass
    assert len(mb.batch_sizes) == len(mb.padded_sizes)
    assert sum(mb.batch_sizes) == 300
    assert all(p >= b and p in (1, 2, 4, 8)
               for b, p in zip(mb.batch_sizes, mb.padded_sizes))


def test_micro_batcher_condvar_wakeup_no_hot_spin():
    """A flusher blocked in flush_loop_once with an empty queue wakes on
    submit (condition variable), and max_wait_s=0 returns immediately
    instead of hot-spinning."""
    import threading

    mb = MicroBatcher(lambda reqs: [r.payload for r in reqs],
                      max_batch=4, max_wait_s=5.0)
    out = []

    def flusher():
        out.append(mb.flush_loop_once())

    t = threading.Thread(target=flusher)
    t.start()
    time.sleep(0.05)                  # flusher is parked on the condvar
    t0 = time.perf_counter()
    futs = [mb.submit(Request("c", j)) for j in range(4)]
    [f.result(timeout=2) for f in futs]
    # the full batch released the flusher long before the 5 s deadline
    assert time.perf_counter() - t0 < 2.0
    t.join(timeout=5)
    assert out == [4]
    # and max_wait_s=0 with an empty queue returns without spinning
    mb0 = MicroBatcher(lambda reqs: [r.payload for r in reqs],
                       max_batch=4, max_wait_s=0.0)
    t0 = time.perf_counter()
    assert mb0.flush_loop_once() == 0
    assert time.perf_counter() - t0 < 0.5


def test_micro_batcher_dispatch_mode_two_in_flight():
    """Continuous mode: a launch's futures resolve only when the batcher
    retires it (max_inflight later launches, or sync()), and the
    completion thunks run in launch order."""
    completed = []

    def dispatch(reqs):
        payloads = [r.payload for r in reqs]

        def complete():
            completed.append(payloads[0])
            return payloads
        return complete

    mb = MicroBatcher(dispatch_batch=dispatch, max_batch=2,
                      max_wait_s=0.0, buckets=(2,), max_inflight=2)
    f01 = [mb.submit(Request(f"c{j}", j)) for j in (0, 1)]
    assert mb.flush_loop_once() == 2
    # one launch in flight: nothing completed, futures pending
    assert mb.inflight == 1 and completed == []
    assert not f01[0].done()
    f23 = [mb.submit(Request(f"c{j}", j)) for j in (2, 3)]
    assert mb.flush_loop_once() == 2
    # second launch hit max_inflight: the FIRST launch retired
    assert mb.inflight == 1 and completed == [0]
    assert [f.result(timeout=1) for f in f01] == [0, 1]
    assert not f23[0].done()
    mb.sync()                          # quiesce retires the rest
    assert mb.inflight == 0 and completed == [0, 2]
    assert [f.result(timeout=1) for f in f23] == [2, 3]


def test_micro_batcher_dispatch_mode_error_paths():
    """Continuous mode errors: a throwing dispatch fails the batch's
    futures immediately; a throwing completion fails them at
    retirement."""
    def bad_dispatch(reqs):
        raise RuntimeError("launch failed")

    mb = MicroBatcher(dispatch_batch=bad_dispatch, max_batch=2,
                      max_wait_s=0.0)
    fut = mb.submit(Request("c", 1))
    mb.flush_loop_once()
    with pytest.raises(RuntimeError, match="launch failed"):
        fut.result(timeout=1)
    assert mb.inflight == 0

    def bad_complete(reqs):
        def complete():
            raise RuntimeError("device error")
        return complete

    mb2 = MicroBatcher(dispatch_batch=bad_complete, max_batch=2,
                       max_wait_s=0.0)
    fut2 = mb2.submit(Request("c", 1))
    mb2.flush_loop_once()
    assert not fut2.done()             # still in flight
    mb2.sync()
    with pytest.raises(RuntimeError, match="device error"):
        fut2.result(timeout=1)


def test_micro_batcher_requires_exactly_one_callback():
    with pytest.raises(ValueError):
        MicroBatcher()
    with pytest.raises(ValueError):
        MicroBatcher(lambda reqs: [], dispatch_batch=lambda reqs: None)


def test_hedged_executor_close_shuts_the_pool_down():
    """close() shuts the replica pool down (idempotently); calls after
    close fail loudly instead of hanging; the context-manager form
    closes on exit."""
    ex = HedgedExecutor([lambda x: x])
    assert ex.call(1) == 1
    ex.close()
    ex.close()                         # idempotent
    assert ex._pool._shutdown
    with pytest.raises(RuntimeError, match="HedgedExecutor is closed"):
        ex.call(2)
    with HedgedExecutor([lambda x: x * 2]) as ex2:
        assert ex2.call(3) == 6
    assert ex2._pool._shutdown
