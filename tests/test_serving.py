"""Serving engine + scheduler behaviour."""
import time

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import ivf
from repro.serving.engine import ConversationalSearchEngine, ServingConfig
from repro.serving.scheduler import (HedgedExecutor, MicroBatcher, Request)


@pytest.fixture(scope="module")
def engine_setup(small_corpus_mod):
    wl = small_corpus_mod
    idx = ivf.build(jnp.asarray(wl.doc_vecs), p=32, iters=4,
                    key=jax.random.PRNGKey(0))
    return wl, idx


@pytest.fixture(scope="module")
def small_corpus_mod():
    from repro.data import synthetic as SY
    return SY.make_workload(SY.WorkloadConfig(
        n_docs=2000, d=32, n_topics=16, n_conversations=3,
        turns_per_conversation=5, seed=1))


def test_session_lifecycle(engine_setup):
    wl, idx = engine_setup
    eng = ConversationalSearchEngine(
        ServingConfig(backend="ivf", strategy="toploc", nprobe=4, h=8,
                      k=10), ivf_index=idx)
    for t in range(3):
        v, i = eng.query("c0", jnp.asarray(wl.conversations[0, t]))
        assert v.shape == (10,) and i.shape == (10,)
    assert "c0" in eng.sessions
    # turn 0 pays the full scan; later turns pay h
    assert eng.records[0].centroid_dists == idx.p
    assert eng.records[1].centroid_dists == 8
    eng.end_conversation("c0")
    assert "c0" not in eng.sessions


def test_strategies_work_ordering(engine_setup):
    """plain pays p per turn; toploc pays h << p after turn 0."""
    wl, idx = engine_setup
    work = {}
    for strat in ("plain", "toploc", "toploc+"):
        eng = ConversationalSearchEngine(
            ServingConfig(backend="ivf", strategy=strat, nprobe=4, h=8,
                          alpha=0.1, k=10), ivf_index=idx)
        for c in range(2):
            for t in range(5):
                eng.query(f"c{c}", jnp.asarray(wl.conversations[c, t]))
        work[strat] = eng.summary()["mean_centroid_dists"]
    assert work["toploc"] < work["plain"]
    assert work["toploc+"] < work["plain"]


def test_exact_backend(engine_setup):
    wl, idx = engine_setup
    eng = ConversationalSearchEngine(
        ServingConfig(backend="exact", k=5),
        doc_vecs=jnp.asarray(wl.doc_vecs))
    v, i = eng.query("c", jnp.asarray(wl.conversations[0, 0]))
    ev, ei = ivf.exact_search(jnp.asarray(wl.doc_vecs),
                              jnp.asarray(wl.conversations[0, :1]), 5)
    np.testing.assert_array_equal(i, np.asarray(ei[0]))


def test_micro_batcher_flushes():
    seen = []

    def process(reqs):
        seen.append(len(reqs))
        return [r.payload * 2 for r in reqs]

    mb = MicroBatcher(process, max_batch=4, max_wait_s=0.01)
    futs = [mb.submit(Request("c", i)) for i in range(6)]
    mb.flush_loop_once()
    mb.flush_loop_once()
    assert [f.result(timeout=1) for f in futs] == [0, 2, 4, 6, 8, 10]
    assert seen[0] == 4 and seen[1] == 2


def test_micro_batcher_propagates_errors():
    def process(reqs):
        raise RuntimeError("boom")

    mb = MicroBatcher(process, max_batch=2, max_wait_s=0.001)
    fut = mb.submit(Request("c", 1))
    mb.flush_loop_once()
    with pytest.raises(RuntimeError):
        fut.result(timeout=1)


def test_hedged_executor_mitigates_straggler():
    def fast(x):
        return ("fast", x)

    def slow(x):
        time.sleep(0.25)
        return ("slow", x)

    # round-robin alternates; hedging should rescue calls landing on slow
    ex = HedgedExecutor([fast, slow], hedge_quantile=0.5, min_history=4,
                        hedge_floor_s=0.02)
    results = [ex.call(i) for i in range(12)]
    st = ex.stats()
    assert st["hedges_issued"] > 0
    assert st["hedges_won"] > 0
    # every call returned a correct payload
    assert all(r[1] == i for i, r in enumerate(results))
    # p99 stays well under the slow replica's latency x2
    assert st["p99_ms"] < 600
