"""Batched multi-conversation serving: equivalence + SessionStore.

The contract under test: serving B concurrent conversations through one
batched dispatch (``toploc.*_batch`` / ``BatchedConversationalSearchEngine``)
is *bit-identical* — scores, ids, and every ``TurnStats`` field — to
serving them one at a time through the sequential path.  This is what
makes the batched path a drop-in: no effectiveness re-evaluation is
needed when the only change is the batching.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import hnsw, ivf, toploc
from repro.serving import (BatchedConversationalSearchEngine,
                           ConversationalSearchEngine, ServingConfig,
                           SessionStore, hnsw_session_store,
                           ivf_session_store)

B, T = 4, 4          # ≥ 4 interleaved conversations
K, H, NPROBE, EF, UP = 10, 16, 4, 16, 2


@pytest.fixture(scope="module")
def convs(small_corpus):
    return jnp.asarray(small_corpus.conversations[:B, :T])


def _stats_equal(seq_stats_rows, batched_stats):
    """Every TurnStats field equal between stacked sequential rows and
    one batched TurnStats."""
    for f in toploc.TurnStats._fields:
        seq = jnp.stack([getattr(s, f) for s in seq_stats_rows])
        if not bool((seq == getattr(batched_stats, f)).all()):
            return False
    return True


# ------------------------------------------------------------------ IVF

@pytest.mark.parametrize("alpha", [-1.0, 0.3])
def test_ivf_batch_equals_sequential(ivf_index, convs, alpha):
    idx = ivf_index
    # sequential: B independent conversations
    sess, vs, is_, sts = [], [], [], []
    for b in range(B):
        v, i, s, st = toploc.ivf_start(idx, convs[b, 0], h=H, nprobe=NPROBE,
                                       k=K)
        sess.append(s)
        vs.append([v]); is_.append([i]); sts.append([st])
    for t in range(1, T):
        for b in range(B):
            v, i, s, st = toploc.ivf_step(idx, sess[b], convs[b, t],
                                          nprobe=NPROBE, k=K, alpha=alpha)
            sess[b] = s
            vs[b].append(v); is_[b].append(i); sts[b].append(st)

    # batched: one dispatch per turn over all B conversations
    bv, bi, bsess, bst = toploc.ivf_start_batch(idx, convs[:, 0], h=H,
                                                nprobe=NPROBE, k=K)
    assert bool((jnp.stack([vs[b][0] for b in range(B)]) == bv).all())
    assert bool((jnp.stack([is_[b][0] for b in range(B)]) == bi).all())
    assert _stats_equal([sts[b][0] for b in range(B)], bst)
    for t in range(1, T):
        bv, bi, bsess, bst = toploc.ivf_step_batch(
            idx, bsess, convs[:, t], nprobe=NPROBE, k=K, alpha=alpha)
        assert bool((jnp.stack([vs[b][t] for b in range(B)]) == bv).all()), t
        assert bool((jnp.stack([is_[b][t] for b in range(B)]) == bi).all()), t
        assert _stats_equal([sts[b][t] for b in range(B)], bst), t
    # final session state also matches (cache, anchors, counters)
    for f in toploc.IVFSession._fields:
        seq = jnp.stack([getattr(sess[b], f) for b in range(B)])
        assert bool((seq == getattr(bsess, f)).all()), f


def test_ivf_mixed_first_and_followup_batch(ivf_index, convs):
    """One batch mixing first turns and follow-ups via the is_first mask
    reproduces ivf_start rows and ivf_step rows exactly."""
    idx = ivf_index
    alpha = 0.3
    v0, i0_, sess0, st0 = toploc.ivf_start_batch(idx, convs[:, 0], h=H,
                                                 nprobe=NPROBE, k=K)
    first = jnp.asarray([True, False, True, False])
    qmix = jnp.where(first[:, None], convs[:, 0], convs[:, 1])
    mv, mi, msess, mst = toploc.ivf_step_batch(
        idx, sess0, qmix, nprobe=NPROBE, k=K, alpha=alpha, is_first=first)
    for b in range(B):
        if bool(first[b]):
            rv, ri, rs, rst = toploc.ivf_start(idx, convs[b, 0], h=H,
                                               nprobe=NPROBE, k=K)
        else:
            sb = jax.tree.map(lambda a: a[b], sess0)
            rv, ri, rs, rst = toploc.ivf_step(idx, sb, convs[b, 1],
                                              nprobe=NPROBE, k=K,
                                              alpha=alpha)
        assert bool((mv[b] == rv).all()) and bool((mi[b] == ri).all()), b
        for f in toploc.TurnStats._fields:
            assert bool((getattr(mst, f)[b] == getattr(rst, f)).all()), (b, f)
        for f in toploc.IVFSession._fields:
            assert bool((jax.tree.map(lambda a: a[b], msess)._asdict()[f]
                         == getattr(rs, f)).all()), (b, f)


# ----------------------------------------------------------------- HNSW

def test_hnsw_batch_equals_sequential(hnsw_index, convs):
    idx = hnsw_index
    sess, vs, is_, sts = [], [], [], []
    for b in range(B):
        v, i, s, st = toploc.hnsw_start(idx, convs[b, 0], ef=EF, k=K, up=UP)
        sess.append(s)
        vs.append([v]); is_.append([i]); sts.append([st])
    for t in range(1, T):
        for b in range(B):
            v, i, s, st = toploc.hnsw_step(idx, sess[b], convs[b, t],
                                           ef=EF, k=K)
            sess[b] = s
            vs[b].append(v); is_[b].append(i); sts[b].append(st)

    bv, bi, bsess, bst = toploc.hnsw_start_batch(idx, convs[:, 0], ef=EF,
                                                 k=K, up=UP)
    assert bool((jnp.stack([vs[b][0] for b in range(B)]) == bv).all())
    assert bool((jnp.stack([is_[b][0] for b in range(B)]) == bi).all())
    assert _stats_equal([sts[b][0] for b in range(B)], bst)
    for t in range(1, T):
        bv, bi, bsess, bst = toploc.hnsw_step_batch(idx, bsess, convs[:, t],
                                                    ef=EF, k=K)
        assert bool((jnp.stack([vs[b][t] for b in range(B)]) == bv).all()), t
        assert bool((jnp.stack([is_[b][t] for b in range(B)]) == bi).all()), t
        assert _stats_equal([sts[b][t] for b in range(B)], bst), t
    assert bool((jnp.stack([s.entry_point for s in sess])
                 == bsess.entry_point).all())


def test_hnsw_mixed_first_and_followup_batch(hnsw_index, convs):
    idx = hnsw_index
    _, _, sess0, _ = toploc.hnsw_start_batch(idx, convs[:, 0], ef=EF, k=K,
                                             up=UP)
    first = jnp.asarray([False, True, False, True])
    qmix = jnp.where(first[:, None], convs[:, 0], convs[:, 1])
    mv, mi, msess, mst = toploc.hnsw_step_batch(
        idx, sess0, qmix, ef=EF, k=K, up=UP, is_first=first)
    for b in range(B):
        if bool(first[b]):
            rv, ri, rs, rst = toploc.hnsw_start(idx, convs[b, 0], ef=EF,
                                                k=K, up=UP)
        else:
            sb = jax.tree.map(lambda a: a[b], sess0)
            rv, ri, rs, rst = toploc.hnsw_step(idx, sb, convs[b, 1],
                                               ef=EF, k=K)
        assert bool((mv[b] == rv).all()) and bool((mi[b] == ri).all()), b
        assert int(mst.graph_dists[b]) == int(rst.graph_dists)
        assert bool(mst.refreshed[b]) == bool(rst.refreshed)
        assert int(msess.entry_point[b]) == int(rs.entry_point)
        assert int(msess.turn[b]) == int(rs.turn)


# --------------------------------------------------------- SessionStore

def test_session_store_slot_reuse_and_eviction(ivf_index):
    store = ivf_session_store(ivf_index, h=H, nprobe=NPROBE, n_slots=2)
    s0, new0 = store.acquire("a")
    s1, new1 = store.acquire("b")
    assert new0 and new1 and s0 != s1
    # reuse: same conv → same slot, not new
    assert store.acquire("a") == (s0, False)
    # full store: 'b' is now LRU ('a' was just touched) → 'c' evicts 'b'
    s2, new2 = store.acquire("c")
    assert new2 and s2 == s1
    assert store.evictions == 1
    assert store.lookup("b") is None
    # evicted conv returning is a fresh allocation (first-turn semantics)
    s3, new3 = store.acquire("b")
    assert new3
    # release returns the slot to the free list for reuse
    freed = store.release("c")
    s4, new4 = store.acquire("d")
    assert new4 and s4 == freed
    assert store.occupancy == 2


def test_session_store_gather_scatter_roundtrip(ivf_index):
    store = ivf_session_store(ivf_index, h=H, nprobe=NPROBE, n_slots=4)
    slots = [store.acquire(f"c{j}")[0] for j in range(3)]
    sess = store.gather(slots)
    bumped = sess._replace(turn=sess.turn + jnp.arange(3, dtype=jnp.int32))
    store.scatter(slots, bumped)
    back = store.gather(slots)
    assert bool((back.turn == jnp.arange(3)).all())
    # trash slot absorbs padded rows without touching live sessions
    pad_slots = [slots[0], store.trash_slot]
    pad = store.gather(pad_slots)
    store.scatter([store.trash_slot, store.trash_slot],
                  jax.tree.map(lambda a: a + 1 if a.dtype == jnp.int32
                               else a, pad))
    assert bool((store.gather(slots).turn == jnp.arange(3)).all())


def test_hnsw_session_store_layout(hnsw_index):
    store = hnsw_session_store(hnsw_index, n_slots=3)
    assert store.gather([0]).entry_point.shape == (1,)
    assert store.trash_slot == 3


# ------------------------------------------------------ batched engine

@pytest.mark.parametrize("backend,strategy", [
    ("ivf", "toploc"), ("ivf", "toploc+"), ("ivf", "plain"),
    ("hnsw", "toploc"),
])
def test_batched_engine_matches_sequential(small_corpus, ivf_index,
                                           hnsw_index, backend, strategy):
    wl = small_corpus
    cfg = ServingConfig(backend=backend, strategy=strategy, nprobe=NPROBE,
                        h=H, alpha=0.3, ef_search=EF, up=UP, k=K)
    seq = ConversationalSearchEngine(cfg, ivf_index=ivf_index,
                                     hnsw_index=hnsw_index)
    bat = BatchedConversationalSearchEngine(
        cfg, ivf_index=ivf_index, hnsw_index=hnsw_index, max_batch=4,
        max_wait_s=1e-4)
    for t in range(T):
        futs = []
        for c in range(4):
            qv = jnp.asarray(wl.conversations[c, t])
            sv, si = seq.query(f"c{c}", qv)
            futs.append((sv, si, bat.submit(f"c{c}", qv)))
        bat.drain()
        for sv, si, fut in futs:
            bv, bi = fut.result(timeout=5)
            np.testing.assert_array_equal(sv, bv)
            np.testing.assert_array_equal(si, bi)
    # identical per-turn work accounting, order-independent
    def key(recs):
        return sorted((r.conv_id, r.turn, r.centroid_dists, r.list_dists,
                       r.graph_dists, r.refreshed, r.i0) for r in recs)
    assert key(seq.records) == key(bat.records)


def test_batched_engine_rejects_undersized_store(ivf_index):
    """A wave needs one live slot per conversation: n_slots < max_batch
    would evict a conversation acquired earlier in the same wave."""
    cfg = ServingConfig(backend="ivf", strategy="toploc", nprobe=NPROBE,
                        h=H, k=K)
    with pytest.raises(ValueError, match="n_slots"):
        BatchedConversationalSearchEngine(cfg, ivf_index=ivf_index,
                                          n_slots=4, max_batch=32)


def test_batched_engine_max_batch_beyond_buckets(small_corpus, ivf_index):
    """max_batch above the largest default bucket gets its own bucket
    instead of overflowing the padded arrays."""
    wl = small_corpus
    cfg = ServingConfig(backend="ivf", strategy="toploc", nprobe=NPROBE,
                        h=H, k=K)
    seq = ConversationalSearchEngine(cfg, ivf_index=ivf_index)
    bat = BatchedConversationalSearchEngine(
        cfg, ivf_index=ivf_index, n_slots=64, max_batch=64,
        max_wait_s=1e-4)
    assert bat.batcher.bucket(64) == 64
    futs = [bat.submit(f"c{c}", jnp.asarray(wl.conversations[c % 4, 0]))
            for c in range(40)]                  # one 40-row wave → bucket 64
    bat.drain()
    for c, fut in enumerate(futs):
        sv, si = seq.query(f"s{c}", jnp.asarray(wl.conversations[c % 4, 0]))
        bv, bi = fut.result(timeout=5)
        np.testing.assert_array_equal(si, bi)
        np.testing.assert_array_equal(sv, bv)


def test_batched_engine_waves_same_conversation(small_corpus, ivf_index):
    """Two turns of one conversation in a single flush are served in
    consecutive waves — the second sees the first's updated session."""
    wl = small_corpus
    cfg = ServingConfig(backend="ivf", strategy="toploc", nprobe=NPROBE,
                        h=H, k=K)
    seq = ConversationalSearchEngine(cfg, ivf_index=ivf_index)
    bat = BatchedConversationalSearchEngine(
        cfg, ivf_index=ivf_index, max_batch=8, max_wait_s=1e-4)
    futs = [bat.submit("c0", jnp.asarray(wl.conversations[0, t]))
            for t in range(3)]
    bat.drain()
    for t, fut in enumerate(futs):
        sv, si = seq.query("c0", jnp.asarray(wl.conversations[0, t]))
        bv, bi = fut.result(timeout=5)
        np.testing.assert_array_equal(si, bi)
        np.testing.assert_array_equal(sv, bv)
    assert [r.turn for r in bat.records] == [0, 1, 2]


def test_batched_engine_padding_never_corrupts_sessions(small_corpus,
                                                        ivf_index):
    """A batch of 3 pads to bucket 4; the padded row lands in the trash
    slot and follow-up turns stay bit-identical to sequential."""
    wl = small_corpus
    cfg = ServingConfig(backend="ivf", strategy="toploc+", nprobe=NPROBE,
                        h=H, alpha=0.3, k=K)
    seq = ConversationalSearchEngine(cfg, ivf_index=ivf_index)
    bat = BatchedConversationalSearchEngine(
        cfg, ivf_index=ivf_index, max_batch=4, max_wait_s=1e-4)
    for t in range(T):
        futs = []
        for c in range(3):                      # 3 → padded to 4
            qv = jnp.asarray(wl.conversations[c, t])
            futs.append((seq.query(f"c{c}", qv), bat.submit(f"c{c}", qv)))
        bat.drain()
        for (sv, si), fut in futs:
            bv, bi = fut.result(timeout=5)
            np.testing.assert_array_equal(si, bi)
            np.testing.assert_array_equal(sv, bv)
