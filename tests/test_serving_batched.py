"""Batched multi-conversation serving: equivalence + SessionStore.

The contract under test: serving B concurrent conversations through one
batched dispatch (``toploc.*_batch`` registry drivers /
``BatchedConversationalSearchEngine``) is *bit-identical* — scores, ids,
and every ``TurnStats`` field — to serving them one at a time through
the sequential path.  This is what makes the batched path a drop-in: no
effectiveness re-evaluation is needed when the only change is the
batching.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import hnsw, ivf, toploc
from repro.core.backend import HNSWBackend, IVFBackend, IVFPQBackend
from repro.serving import (BatchedConversationalSearchEngine,
                           ConversationalSearchEngine, ServingConfig,
                           SessionStore, hnsw_session_store,
                           ivf_session_store)

B, T = 4, 4          # ≥ 4 interleaved conversations
K, H, NPROBE, EF, UP = 10, 16, 4, 16, 2


@pytest.fixture(scope="module")
def convs(small_corpus):
    return jnp.asarray(small_corpus.conversations[:B, :T])


def _stats_equal(seq_stats_rows, batched_stats):
    """Every TurnStats field equal between stacked sequential rows and
    one batched TurnStats."""
    for f in toploc.TurnStats._fields:
        seq = jnp.stack([getattr(s, f) for s in seq_stats_rows])
        if not bool((seq == getattr(batched_stats, f)).all()):
            return False
    return True


# ------------------------------------------------------------------ IVF

@pytest.mark.parametrize("alpha", [-1.0, 0.3])
def test_ivf_batch_equals_sequential(ivf_index, convs, alpha):
    idx = ivf_index
    bk = IVFBackend(h=H, nprobe=NPROBE, alpha=alpha)
    # sequential: B independent conversations
    sess, vs, is_, sts = [], [], [], []
    for b in range(B):
        v, i, s, st = toploc.start(bk, idx, convs[b, 0], k=K)
        sess.append(s)
        vs.append([v]); is_.append([i]); sts.append([st])
    for t in range(1, T):
        for b in range(B):
            v, i, s, st = toploc.step(bk, idx, sess[b], convs[b, t], k=K)
            sess[b] = s
            vs[b].append(v); is_[b].append(i); sts[b].append(st)

    # batched: one dispatch per turn over all B conversations
    bv, bi, bsess, bst = toploc.start_batch(bk, idx, convs[:, 0], k=K)
    assert bool((jnp.stack([vs[b][0] for b in range(B)]) == bv).all())
    assert bool((jnp.stack([is_[b][0] for b in range(B)]) == bi).all())
    assert _stats_equal([sts[b][0] for b in range(B)], bst)
    for t in range(1, T):
        bv, bi, bsess, bst = toploc.step_batch(bk, idx, bsess, convs[:, t],
                                               k=K)
        assert bool((jnp.stack([vs[b][t] for b in range(B)]) == bv).all()), t
        assert bool((jnp.stack([is_[b][t] for b in range(B)]) == bi).all()), t
        assert _stats_equal([sts[b][t] for b in range(B)], bst), t
    # final session state also matches (cache, anchors, counters)
    for f in toploc.IVFSession._fields:
        seq = jnp.stack([getattr(sess[b], f) for b in range(B)])
        assert bool((seq == getattr(bsess, f)).all()), f


def test_ivf_mixed_first_and_followup_batch(ivf_index, convs):
    """One batch mixing first turns and follow-ups via the is_first mask
    reproduces start rows and step rows exactly."""
    idx = ivf_index
    bk = IVFBackend(h=H, nprobe=NPROBE, alpha=0.3)
    v0, i0_, sess0, st0 = toploc.start_batch(bk, idx, convs[:, 0], k=K)
    first = jnp.asarray([True, False, True, False])
    qmix = jnp.where(first[:, None], convs[:, 0], convs[:, 1])
    mv, mi, msess, mst = toploc.step_batch(bk, idx, sess0, qmix, k=K,
                                           is_first=first)
    for b in range(B):
        if bool(first[b]):
            rv, ri, rs, rst = toploc.start(bk, idx, convs[b, 0], k=K)
        else:
            sb = jax.tree.map(lambda a: a[b], sess0)
            rv, ri, rs, rst = toploc.step(bk, idx, sb, convs[b, 1], k=K)
        assert bool((mv[b] == rv).all()) and bool((mi[b] == ri).all()), b
        for f in toploc.TurnStats._fields:
            assert bool((getattr(mst, f)[b] == getattr(rst, f)).all()), (b, f)
        for f in toploc.IVFSession._fields:
            assert bool((jax.tree.map(lambda a: a[b], msess)._asdict()[f]
                         == getattr(rs, f)).all()), (b, f)


# --------------------------------------------------------------- IVF-PQ

@pytest.mark.parametrize("alpha", [-1.0, 0.3])
def test_ivf_pq_batch_equals_sequential(ivf_pq_index, convs, alpha):
    idx = ivf_pq_index
    bk = IVFPQBackend(h=H, nprobe=NPROBE, alpha=alpha, rerank=32)
    sess, vs, is_, sts = [], [], [], []
    for b in range(B):
        v, i, s, st = toploc.start(bk, idx, convs[b, 0], k=K)
        sess.append(s)
        vs.append([v]); is_.append([i]); sts.append([st])
    for t in range(1, T):
        for b in range(B):
            v, i, s, st = toploc.step(bk, idx, sess[b], convs[b, t], k=K)
            sess[b] = s
            vs[b].append(v); is_[b].append(i); sts[b].append(st)

    bv, bi, bsess, bst = toploc.start_batch(bk, idx, convs[:, 0], k=K)
    assert bool((jnp.stack([vs[b][0] for b in range(B)]) == bv).all())
    assert bool((jnp.stack([is_[b][0] for b in range(B)]) == bi).all())
    assert _stats_equal([sts[b][0] for b in range(B)], bst)
    for t in range(1, T):
        bv, bi, bsess, bst = toploc.step_batch(bk, idx, bsess, convs[:, t],
                                               k=K)
        assert bool((jnp.stack([vs[b][t] for b in range(B)]) == bv).all()), t
        assert bool((jnp.stack([is_[b][t] for b in range(B)]) == bi).all()), t
        assert _stats_equal([sts[b][t] for b in range(B)], bst), t
    for f in toploc.IVFSession._fields:
        seq = jnp.stack([getattr(sess[b], f) for b in range(B)])
        assert bool((seq == getattr(bsess, f)).all()), f


def test_ivf_pq_mixed_first_and_followup_batch(ivf_pq_index, convs):
    idx = ivf_pq_index
    bk = IVFPQBackend(h=H, nprobe=NPROBE, alpha=0.3, rerank=32)
    _, _, sess0, _ = toploc.start_batch(bk, idx, convs[:, 0], k=K)
    first = jnp.asarray([True, False, True, False])
    qmix = jnp.where(first[:, None], convs[:, 0], convs[:, 1])
    mv, mi, msess, mst = toploc.step_batch(bk, idx, sess0, qmix, k=K,
                                           is_first=first)
    for b in range(B):
        if bool(first[b]):
            rv, ri, rs, rst = toploc.start(bk, idx, convs[b, 0], k=K)
        else:
            sb = jax.tree.map(lambda a: a[b], sess0)
            rv, ri, rs, rst = toploc.step(bk, idx, sb, convs[b, 1], k=K)
        assert bool((mv[b] == rv).all()) and bool((mi[b] == ri).all()), b
        for f in toploc.TurnStats._fields:
            assert bool((getattr(mst, f)[b] == getattr(rst, f)).all()), (b, f)
        for f in toploc.IVFSession._fields:
            assert bool((jax.tree.map(lambda a: a[b], msess)._asdict()[f]
                         == getattr(rs, f)).all()), (b, f)


# ----------------------------------------------------------------- HNSW

def test_hnsw_batch_equals_sequential(hnsw_index, convs):
    idx = hnsw_index
    bk = HNSWBackend(ef=EF, up=UP)
    sess, vs, is_, sts = [], [], [], []
    for b in range(B):
        v, i, s, st = toploc.start(bk, idx, convs[b, 0], k=K)
        sess.append(s)
        vs.append([v]); is_.append([i]); sts.append([st])
    for t in range(1, T):
        for b in range(B):
            v, i, s, st = toploc.step(bk, idx, sess[b], convs[b, t], k=K)
            sess[b] = s
            vs[b].append(v); is_[b].append(i); sts[b].append(st)

    bv, bi, bsess, bst = toploc.start_batch(bk, idx, convs[:, 0], k=K)
    assert bool((jnp.stack([vs[b][0] for b in range(B)]) == bv).all())
    assert bool((jnp.stack([is_[b][0] for b in range(B)]) == bi).all())
    assert _stats_equal([sts[b][0] for b in range(B)], bst)
    for t in range(1, T):
        bv, bi, bsess, bst = toploc.step_batch(bk, idx, bsess, convs[:, t],
                                               k=K)
        assert bool((jnp.stack([vs[b][t] for b in range(B)]) == bv).all()), t
        assert bool((jnp.stack([is_[b][t] for b in range(B)]) == bi).all()), t
        assert _stats_equal([sts[b][t] for b in range(B)], bst), t
    assert bool((jnp.stack([s.entry_point for s in sess])
                 == bsess.entry_point).all())


def test_hnsw_mixed_first_and_followup_batch(hnsw_index, convs):
    idx = hnsw_index
    bk = HNSWBackend(ef=EF, up=UP)
    _, _, sess0, _ = toploc.start_batch(bk, idx, convs[:, 0], k=K)
    first = jnp.asarray([False, True, False, True])
    qmix = jnp.where(first[:, None], convs[:, 0], convs[:, 1])
    mv, mi, msess, mst = toploc.step_batch(bk, idx, sess0, qmix, k=K,
                                           is_first=first)
    for b in range(B):
        if bool(first[b]):
            rv, ri, rs, rst = toploc.start(bk, idx, convs[b, 0], k=K)
        else:
            sb = jax.tree.map(lambda a: a[b], sess0)
            rv, ri, rs, rst = toploc.step(bk, idx, sb, convs[b, 1], k=K)
        assert bool((mv[b] == rv).all()) and bool((mi[b] == ri).all()), b
        assert int(mst.graph_dists[b]) == int(rst.graph_dists)
        assert bool(mst.refreshed[b]) == bool(rst.refreshed)
        assert int(msess.entry_point[b]) == int(rs.entry_point)
        assert int(msess.turn[b]) == int(rs.turn)


# --------------------------------------------------------- SessionStore

def test_session_store_slot_reuse_and_eviction(ivf_index):
    store = ivf_session_store(ivf_index, h=H, nprobe=NPROBE, n_slots=2)
    s0, new0 = store.acquire("a")
    s1, new1 = store.acquire("b")
    assert new0 and new1 and s0 != s1
    # reuse: same conv → same slot, not new
    assert store.acquire("a") == (s0, False)
    # full store: 'b' is now LRU ('a' was just touched) → 'c' evicts 'b'
    s2, new2 = store.acquire("c")
    assert new2 and s2 == s1
    assert store.evictions == 1
    assert store.lookup("b") is None
    # evicted conv returning is a fresh allocation (first-turn semantics)
    s3, new3 = store.acquire("b")
    assert new3
    # release returns the slot to the free list for reuse
    freed = store.release("c")
    s4, new4 = store.acquire("d")
    assert new4 and s4 == freed
    assert store.occupancy == 2


def test_session_store_gather_scatter_roundtrip(ivf_index):
    store = ivf_session_store(ivf_index, h=H, nprobe=NPROBE, n_slots=4)
    slots = [store.acquire(f"c{j}")[0] for j in range(3)]
    sess = store.gather(slots)
    bumped = sess._replace(turn=sess.turn + jnp.arange(3, dtype=jnp.int32))
    store.scatter(slots, bumped)
    back = store.gather(slots)
    assert bool((back.turn == jnp.arange(3)).all())
    # trash slot absorbs padded rows without touching live sessions
    pad_slots = [slots[0], store.trash_slot]
    pad = store.gather(pad_slots)
    store.scatter([store.trash_slot, store.trash_slot],
                  jax.tree.map(lambda a: a + 1 if a.dtype == jnp.int32
                               else a, pad))
    assert bool((store.gather(slots).turn == jnp.arange(3)).all())


def test_hnsw_session_store_layout(hnsw_index):
    store = hnsw_session_store(hnsw_index, n_slots=3)
    assert store.gather([0]).entry_point.shape == (1,)
    assert store.trash_slot == 3


def test_release_zeroes_slab_row_and_is_idempotent(ivf_index):
    """A released slot's slab row is wiped (no prior-conversation cache
    can leak to the next occupant) and double-release is a no-op — in
    particular the slot is never double-appended to the free list."""
    store = ivf_session_store(ivf_index, h=H, nprobe=NPROBE, n_slots=2)
    slot, _ = store.acquire("a")
    dirty = jax.tree.map(lambda a: a + 1 if a.dtype == jnp.int32 else a + 1.0,
                         store.gather([slot]))
    store.scatter([slot], dirty)
    assert int(store.gather([slot]).turn[0]) == 1
    freed = store.release("a")
    assert freed == slot
    row = store.gather([slot])
    for f in toploc.IVFSession._fields:
        assert bool((getattr(row, f) == 0).all()), f
    # idempotent: second release returns None and does not corrupt the
    # free list (a duplicate entry would hand one slot to two convs)
    n_free = len(store._free)
    assert store.release("a") is None
    assert len(store._free) == n_free
    s1, _ = store.acquire("x")
    s2, _ = store.acquire("y")
    assert s1 != s2


def test_eviction_zeroes_slab_row_before_slot_reuse(ivf_index):
    """LRU eviction is the other way a slot changes hands: the evicted
    conversation's row must be wiped before the new occupant sees it."""
    store = ivf_session_store(ivf_index, h=H, nprobe=NPROBE, n_slots=1)
    slot, _ = store.acquire("old")
    dirty = jax.tree.map(lambda a: a + 1 if a.dtype == jnp.int32 else a + 1.0,
                         store.gather([slot]))
    store.scatter([slot], dirty)
    new_slot, is_new = store.acquire("new")      # evicts "old"
    assert new_slot == slot and is_new and store.evictions == 1
    row = store.gather([new_slot])
    for f in toploc.IVFSession._fields:
        assert bool((getattr(row, f) == 0).all()), f


def test_slot_freed_listeners_fire_on_release_and_eviction(ivf_index):
    """Companion state (e.g. the result-cache slab) keys off the same
    slots; it must observe every slot hand-over."""
    store = ivf_session_store(ivf_index, h=H, nprobe=NPROBE, n_slots=1)
    freed = []
    store.add_slot_freed_listener(freed.append)
    slot, _ = store.acquire("a")
    store.release("a")
    assert freed == [slot]
    slot2, _ = store.acquire("b")
    store.acquire("c")                       # evicts "b"
    assert freed == [slot, slot2]
    store.release("nope")                    # no-op: no notification
    assert freed == [slot, slot2]


def test_release_then_reacquire_never_leaks_prior_cache(small_corpus,
                                                        ivf_index):
    """Engine-level: end_conversation() wipes the slot, so the next
    conversation landing on it starts from zeros even if a buggy caller
    were to skip the is_first rebuild."""
    wl = small_corpus
    cfg = ServingConfig(backend="ivf", strategy="toploc+", nprobe=NPROBE,
                        h=H, alpha=0.3, k=K)
    bat = BatchedConversationalSearchEngine(
        cfg, ivf_index=ivf_index, max_batch=2, max_wait_s=1e-4)
    bat.query("a", jnp.asarray(wl.conversations[0, 0]))
    bat.query("a", jnp.asarray(wl.conversations[0, 1]))
    slot = bat.store.lookup("a")
    bat.end_conversation("a")
    row = bat.store.gather([slot])
    for f in toploc.IVFSession._fields:
        assert bool((getattr(row, f) == 0).all()), f
    # the freed slot's next occupant is served as a clean first turn
    v, i = bat.query("b", jnp.asarray(wl.conversations[1, 0]))
    assert bat.store.lookup("b") == slot
    rv, ri, _, _ = toploc.start(IVFBackend(h=H, nprobe=NPROBE), ivf_index,
                                jnp.asarray(wl.conversations[1, 0]), k=K)
    np.testing.assert_array_equal(v, np.asarray(rv))
    np.testing.assert_array_equal(i, np.asarray(ri))


# ------------------------------------------------------ batched engine

@pytest.mark.parametrize("backend,strategy", [
    ("ivf", "toploc"), ("ivf", "toploc+"), ("ivf", "plain"),
    ("ivf_pq", "toploc"), ("ivf_pq", "toploc+"), ("ivf_pq", "plain"),
    ("hnsw", "toploc"),
])
def test_batched_engine_matches_sequential(small_corpus, ivf_index,
                                           ivf_pq_index, hnsw_index,
                                           backend, strategy):
    wl = small_corpus
    cfg = ServingConfig(backend=backend, strategy=strategy, nprobe=NPROBE,
                        h=H, alpha=0.3, ef_search=EF, up=UP, k=K, rerank=32)
    seq = ConversationalSearchEngine(cfg, ivf_index=ivf_index,
                                     ivf_pq_index=ivf_pq_index,
                                     hnsw_index=hnsw_index)
    bat = BatchedConversationalSearchEngine(
        cfg, ivf_index=ivf_index, ivf_pq_index=ivf_pq_index,
        hnsw_index=hnsw_index, max_batch=4, max_wait_s=1e-4)
    for t in range(T):
        futs = []
        for c in range(4):
            qv = jnp.asarray(wl.conversations[c, t])
            sv, si = seq.query(f"c{c}", qv)
            futs.append((sv, si, bat.submit(f"c{c}", qv)))
        bat.drain()
        for sv, si, fut in futs:
            bv, bi = fut.result(timeout=5)
            np.testing.assert_array_equal(sv, bv)
            np.testing.assert_array_equal(si, bi)
    # identical per-turn work accounting, order-independent
    def key(recs):
        return sorted((r.conv_id, r.turn, r.centroid_dists, r.list_dists,
                       r.graph_dists, r.code_dists, r.refreshed, r.i0)
                      for r in recs)
    assert key(seq.records) == key(bat.records)


def test_batched_engine_rejects_undersized_store(ivf_index):
    """A wave needs one live slot per conversation: n_slots < max_batch
    would evict a conversation acquired earlier in the same wave."""
    cfg = ServingConfig(backend="ivf", strategy="toploc", nprobe=NPROBE,
                        h=H, k=K)
    with pytest.raises(ValueError, match="n_slots"):
        BatchedConversationalSearchEngine(cfg, ivf_index=ivf_index,
                                          n_slots=4, max_batch=32)


def test_batched_engine_max_batch_beyond_buckets(small_corpus, ivf_index):
    """max_batch above the largest default bucket gets its own bucket
    instead of overflowing the padded arrays."""
    wl = small_corpus
    cfg = ServingConfig(backend="ivf", strategy="toploc", nprobe=NPROBE,
                        h=H, k=K)
    seq = ConversationalSearchEngine(cfg, ivf_index=ivf_index)
    bat = BatchedConversationalSearchEngine(
        cfg, ivf_index=ivf_index, n_slots=64, max_batch=64,
        max_wait_s=1e-4)
    assert bat.batcher.bucket(64) == 64
    futs = [bat.submit(f"c{c}", jnp.asarray(wl.conversations[c % 4, 0]))
            for c in range(40)]                  # one 40-row wave → bucket 64
    bat.drain()
    for c, fut in enumerate(futs):
        sv, si = seq.query(f"s{c}", jnp.asarray(wl.conversations[c % 4, 0]))
        bv, bi = fut.result(timeout=5)
        np.testing.assert_array_equal(si, bi)
        np.testing.assert_array_equal(sv, bv)


def test_batched_engine_waves_same_conversation(small_corpus, ivf_index):
    """Two turns of one conversation in a single flush are served in
    consecutive waves — the second sees the first's updated session."""
    wl = small_corpus
    cfg = ServingConfig(backend="ivf", strategy="toploc", nprobe=NPROBE,
                        h=H, k=K)
    seq = ConversationalSearchEngine(cfg, ivf_index=ivf_index)
    bat = BatchedConversationalSearchEngine(
        cfg, ivf_index=ivf_index, max_batch=8, max_wait_s=1e-4)
    futs = [bat.submit("c0", jnp.asarray(wl.conversations[0, t]))
            for t in range(3)]
    bat.drain()
    for t, fut in enumerate(futs):
        sv, si = seq.query("c0", jnp.asarray(wl.conversations[0, t]))
        bv, bi = fut.result(timeout=5)
        np.testing.assert_array_equal(si, bi)
        np.testing.assert_array_equal(sv, bv)
    assert [r.turn for r in bat.records] == [0, 1, 2]


@pytest.mark.parametrize("backend", ["ivf", "ivf_pq"])
def test_evicted_live_conversation_resumes_as_first_turn(
        small_corpus, ivf_index, ivf_pq_index, backend):
    """LRU-evicting a live conversation then resuming it must re-run the
    first-turn path: a fresh start on the *current* utterance, not a
    follow-up step against another conversation's slot contents."""
    wl = small_corpus
    cfg = ServingConfig(backend=backend, strategy="toploc+", nprobe=NPROBE,
                        h=H, alpha=0.3, k=K, rerank=32)
    bat = BatchedConversationalSearchEngine(
        cfg, ivf_index=ivf_index, ivf_pq_index=ivf_pq_index,
        n_slots=2, max_batch=2, max_wait_s=1e-4)
    if backend == "ivf":
        idx, bk = ivf_index, IVFBackend(h=H, nprobe=NPROBE)
    else:
        idx, bk = ivf_pq_index, IVFPQBackend(h=H, nprobe=NPROBE, rerank=32)

    qa0, qa1 = jnp.asarray(wl.conversations[0, 0]), \
        jnp.asarray(wl.conversations[0, 1])
    bat.query("a", qa0)                       # slot 0
    bat.query("b", jnp.asarray(wl.conversations[1, 0]))   # slot 1 (full)
    bat.query("c", jnp.asarray(wl.conversations[2, 0]))   # evicts LRU 'a'
    assert bat.store.evictions == 1
    assert bat.store.lookup("a") is None
    # 'a' returns mid-conversation: must be served as a first turn
    v, i = bat.query("a", qa1)
    rv, ri, _, rst = toploc.start(bk, idx, qa1, k=K)
    np.testing.assert_array_equal(v, np.asarray(rv))
    np.testing.assert_array_equal(i, np.asarray(ri))
    rec = bat.records[-1]
    assert rec.conv_id == "a" and rec.turn == 1      # host turn counter…
    assert rec.centroid_dists == idx.p               # …but first-turn work
    assert rec.refreshed and rec.i0 == -1
    # and the rebuilt session continues as a normal follow-up
    bat.query("a", jnp.asarray(wl.conversations[0, 2]))
    assert bat.records[-1].centroid_dists in (H, H + idx.p)


def test_trash_slot_never_leaks_into_live_rows(small_corpus, ivf_index):
    """A padded trash-slot row must never surface scores to a caller or
    mutate the stats/sessions of real rows."""
    wl = small_corpus
    cfg = ServingConfig(backend="ivf", strategy="toploc+", nprobe=NPROBE,
                        h=H, alpha=0.3, k=K)
    bat = BatchedConversationalSearchEngine(
        cfg, ivf_index=ivf_index, max_batch=4, max_wait_s=1e-4)
    # every flush of 3 pads to bucket 4 → one trash row per flush
    for t in range(3):
        for c in range(3):
            bat.submit(f"c{c}", jnp.asarray(wl.conversations[c, t]))
        bat.drain()
    # no records for the trash row: exactly 3 convs x 3 turns
    assert len(bat.records) == 9
    assert {r.conv_id for r in bat.records} == {"c0", "c1", "c2"}
    assert [r.turn for r in sorted(bat.records,
                                   key=lambda r: (r.conv_id, r.turn))] \
        == [0, 1, 2] * 3
    # live slab rows equal the sequential per-conversation sessions
    bk = IVFBackend(h=H, nprobe=NPROBE, alpha=0.3)
    seq_sess = {}
    for c in range(3):
        v, i, s, _ = toploc.start(bk, ivf_index,
                                  jnp.asarray(wl.conversations[c, 0]), k=K)
        for t in (1, 2):
            v, i, s, _ = toploc.step(bk, ivf_index, s,
                                     jnp.asarray(wl.conversations[c, t]),
                                     k=K)
        seq_sess[f"c{c}"] = s
    for c in range(3):
        slot = bat.store.lookup(f"c{c}")
        row = bat.store.gather([slot])
        for f in toploc.IVFSession._fields:
            assert bool((getattr(row, f)[0]
                         == getattr(seq_sess[f"c{c}"], f)).all()), (c, f)
    # the trash row itself was scattered to (turn bumped) but that state
    # is unreachable: no conversation maps to the trash slot
    assert bat.store.trash_slot not in [
        bat.store.lookup(f"c{c}") for c in range(3)]


def test_batched_engine_padding_never_corrupts_sessions(small_corpus,
                                                        ivf_index):
    """A batch of 3 pads to bucket 4; the padded row lands in the trash
    slot and follow-up turns stay bit-identical to sequential."""
    wl = small_corpus
    cfg = ServingConfig(backend="ivf", strategy="toploc+", nprobe=NPROBE,
                        h=H, alpha=0.3, k=K)
    seq = ConversationalSearchEngine(cfg, ivf_index=ivf_index)
    bat = BatchedConversationalSearchEngine(
        cfg, ivf_index=ivf_index, max_batch=4, max_wait_s=1e-4)
    for t in range(T):
        futs = []
        for c in range(3):                      # 3 → padded to 4
            qv = jnp.asarray(wl.conversations[c, t])
            futs.append((seq.query(f"c{c}", qv), bat.submit(f"c{c}", qv)))
        bat.drain()
        for (sv, si), fut in futs:
            bv, bi = fut.result(timeout=5)
            np.testing.assert_array_equal(si, bi)
            np.testing.assert_array_equal(sv, bv)


# --------------------------------------- accounting + continuous batching

def test_latency_accounting_splits_queue_wait_from_service(small_corpus,
                                                           ivf_index):
    """latency_s is service time in BOTH engines; queueing shows up only
    in the batched engine's queue_wait_s.  refresh_rate (the effectiveness
    proxy) matches between the two on identical interleaved traffic."""
    wl = small_corpus
    cfg = ServingConfig(backend="ivf", strategy="toploc+", nprobe=NPROBE,
                        h=H, alpha=0.3, k=K)
    seq = ConversationalSearchEngine(cfg, ivf_index=ivf_index)
    bat = BatchedConversationalSearchEngine(
        cfg, ivf_index=ivf_index, max_batch=4, max_wait_s=1e-4)
    for t in range(T):
        for c in range(3):
            qv = jnp.asarray(wl.conversations[c, t])
            seq.query(f"c{c}", qv)
            bat.submit(f"c{c}", qv)
        bat.drain()
    assert all(r.queue_wait_s == 0.0 for r in seq.records)
    assert all(r.queue_wait_s >= 0.0 and r.latency_s >= 0.0
               for r in bat.records)
    for s in (seq.summary(), bat.summary()):
        assert {"mean_queue_wait_ms", "p95_request_ms",
                "p95_latency_ms"} <= s.keys()
        # request time = wait + service, so the request p95 dominates
        assert s["p95_request_ms"] >= s["p95_latency_ms"]
    assert seq.summary()["refresh_rate"] == bat.summary()["refresh_rate"]


def test_refresh_rate_counts_followup_turns_only(small_corpus, ivf_index):
    """refresh_rate is the fraction of FOLLOW-UP turns that refreshed.
    With interleaved conversations the records list is not grouped by
    conversation, so 'skip the first record' would miscount — the fix
    filters on r.turn > 0."""
    wl = small_corpus
    cfg = ServingConfig(backend="ivf", strategy="toploc+", nprobe=NPROBE,
                        h=H, alpha=-1.0, k=K)   # alpha<0: never refresh
    eng = ConversationalSearchEngine(cfg, ivf_index=ivf_index)
    # interleave: c0 turn0, c1 turn0, c0 turn1, c1 turn1 — two of the
    # four records are first turns (refreshed=True by convention) and
    # neither sits at records[0]... records[1:] would count one of them
    for t in range(2):
        for c in range(2):
            eng.query(f"c{c}", jnp.asarray(wl.conversations[c, t]))
    first_flags = [(r.turn, r.refreshed) for r in eng.records]
    assert [t for t, _ in first_flags] == [0, 0, 1, 1]
    assert eng.summary()["refresh_rate"] == float(np.mean(
        [r.refreshed for r in eng.records if r.turn > 0]))
    assert eng.summary()["refresh_rate"] == 0.0


def test_two_in_flight_waves_preserve_order_and_identity(small_corpus,
                                                         ivf_index):
    """Continuous batching at the engine level: flush() launches a wave
    and returns before its results are fetched; a later flush of the
    SAME conversations gathers the updated slab rows (device-stream
    ordering through the slab), so repeated flush-without-sync stays
    bit-identical to sequential."""
    wl = small_corpus
    cfg = ServingConfig(backend="ivf", strategy="toploc+", nprobe=NPROBE,
                        h=H, alpha=0.3, k=K)
    seq = ConversationalSearchEngine(cfg, ivf_index=ivf_index)
    with BatchedConversationalSearchEngine(
            cfg, ivf_index=ivf_index, max_batch=4, max_wait_s=1e-4,
            max_inflight=2) as bat:
        futs = []
        for t in range(T):                   # one launched wave per turn,
            for c in range(4):               # never more than 2 retired
                qv = jnp.asarray(wl.conversations[c, t])
                futs.append((seq.query(f"c{c}", qv),
                             bat.submit(f"c{c}", qv)))
            assert bat.flush() == 4
            assert bat.batcher.inflight <= 2
        # with 4 launches and max_inflight=2, the first two waves were
        # retired by later launches — their futures already resolved
        assert futs[0][1].done() and futs[7][1].done()
        assert not futs[-1][1].done()
        bat.sync()
        assert bat.batcher.inflight == 0
        for (sv, si), fut in futs:
            bv, bi = fut.result(timeout=5)
            np.testing.assert_array_equal(si, bi)
            np.testing.assert_array_equal(sv, bv)
        # per-conversation turn order in the records is monotonic
        for c in range(4):
            turns = [r.turn for r in bat.records if r.conv_id == f"c{c}"]
            assert turns == sorted(turns)


def test_same_conversation_across_inflight_flushes(small_corpus, ivf_index):
    """Three turns of one conversation across three un-synced flushes:
    turn t+1's gather must see turn t's scatter even while both waves
    are in flight."""
    wl = small_corpus
    cfg = ServingConfig(backend="ivf", strategy="toploc", nprobe=NPROBE,
                        h=H, k=K)
    seq = ConversationalSearchEngine(cfg, ivf_index=ivf_index)
    with BatchedConversationalSearchEngine(
            cfg, ivf_index=ivf_index, max_batch=2, max_wait_s=1e-4,
            max_inflight=2) as bat:
        futs = []
        for t in range(3):
            futs.append(bat.submit("c0", jnp.asarray(wl.conversations[0, t])))
            bat.flush()
        bat.sync()
        for t, fut in enumerate(futs):
            sv, si = seq.query("c0", jnp.asarray(wl.conversations[0, t]))
            bv, bi = fut.result(timeout=5)
            np.testing.assert_array_equal(si, bi)
            np.testing.assert_array_equal(sv, bv)
        assert [r.turn for r in bat.records] == [0, 1, 2]


def test_end_conversation_waits_for_inflight_waves(small_corpus, ivf_index):
    """Releasing a session while its wave is still in flight must not
    wipe the slab row out from under the pending scatter: the engine
    syncs before release."""
    wl = small_corpus
    cfg = ServingConfig(backend="ivf", strategy="toploc+", nprobe=NPROBE,
                        h=H, alpha=0.3, k=K)
    with BatchedConversationalSearchEngine(
            cfg, ivf_index=ivf_index, max_batch=2, max_wait_s=1e-4,
            max_inflight=2) as bat:
        fut = bat.submit("c0", jnp.asarray(wl.conversations[0, 0]))
        bat.flush()                          # launched, not retired
        bat.end_conversation("c0")           # must sync first
        v, i = fut.result(timeout=5)
        rv, ri, _, _ = toploc.start(
            IVFBackend(h=H, nprobe=NPROBE, alpha=0.3), ivf_index,
            jnp.asarray(wl.conversations[0, 0]), k=K)
        np.testing.assert_array_equal(i, np.asarray(ri))
        np.testing.assert_array_equal(v, np.asarray(rv))
        assert bat.store.lookup("c0") is None


def test_clear_zeroes_many_slots_in_one_dispatch(ivf_index, monkeypatch):
    """clear() tiles the zero template over the batch and issues ONE
    jitted scatter (it used to dispatch once per slot — the result
    cache's tombstone sweep can hand it hundreds of rows at once), with
    slot-freed listener semantics unchanged: clear() itself never
    notifies, release/eviction still notify once after zeroing."""
    from repro.serving import sessions as SS

    store = ivf_session_store(ivf_index, h=H, nprobe=NPROBE, n_slots=8)
    slots = []
    for cid in "abcd":
        s, _ = store.acquire(cid)
        dirty = jax.tree.map(
            lambda a: a + 1 if a.dtype == jnp.int32 else a + 1.0,
            store.gather([s]))
        store.scatter([s], dirty)
        slots.append(s)

    calls = []
    real = SS._scatter_slab

    def counting(slab, idx, updates):
        calls.append(int(idx.shape[0]))
        return real(slab, idx, updates)

    monkeypatch.setattr(SS, "_scatter_slab", counting)
    store.clear(slots)
    assert calls == [len(slots)]             # one batched dispatch
    rows = store.gather(slots)
    for f in toploc.IVFSession._fields:
        assert bool((getattr(rows, f) == 0).all()), f
    store.clear([])                          # empty batch: no dispatch
    assert calls == [len(slots)]

    freed = []
    store.add_slot_freed_listener(freed.append)
    store.clear([slots[1]])
    assert freed == []                       # clear() is not a hand-over
    store.release("a")
    assert freed == [slots[0]]
