"""Launch-layer units that don't need 512 devices: analysis parsing,
roofline math, mesh helpers, serve driver plumbing."""
import pytest

from repro.launch import analysis as AN


SAMPLE_HLO = """
HloModule jit_step

%while_body_1 (p: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %ar = f32[128,256]{1,0} all-reduce(%x), replica_groups=[16,16]<=[256], to_apply=%add
  %ag = bf16[64,512]{1,0} all-gather(%y), replica_groups=[8,32]<=[256], dimensions={1}
}

ENTRY %main () -> f32[] {
  %w = (s32[], f32[128,256]) while(%init), condition=%cond, body=%while_body_1
  %rs = f32[32,64]{1,0} reduce-scatter(%z), replica_groups=[16,16]<=[256]
  %cp = f32[16,16]{1,0} collective-permute(%q), source_target_pairs={{0,1}}
}
"""


def test_collective_parsing():
    total, by_kind = AN.collective_bytes_in(SAMPLE_HLO, 16)
    # all-reduce: 128*256*4 * 2*(16-1)/16
    ar = 128 * 256 * 4 * 2 * 15 / 16
    # all-gather: 64*512*2 * (32-1)/32
    ag = 64 * 512 * 2 * 31 / 32
    # reduce-scatter: 32*64*4 * (16-1)
    rs = 32 * 64 * 4 * 15
    cp = 16 * 16 * 4
    assert by_kind["all-reduce"] == pytest.approx(ar)
    assert by_kind["all-gather"] == pytest.approx(ag)
    assert by_kind["reduce-scatter"] == pytest.approx(rs)
    assert by_kind["collective-permute"] == pytest.approx(cp)
    assert total == pytest.approx(ar + ag + rs + cp)


def test_while_body_detection():
    bodies = AN.while_body_names(SAMPLE_HLO)
    assert "while_body_1" in bodies
    comps = AN.split_computations(SAMPLE_HLO)
    assert any("while_body_1" in k for k in comps)


def test_shape_bytes():
    assert AN._shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert AN._shape_bytes("bf16[8]") == 16
    assert AN._shape_bytes("pred[4,4]") == 16
    # tuple shapes sum elements
    assert AN._shape_bytes("(f32[2], s32[2])") == 16


def test_roofline_terms_math():
    cost = AN.CellCost(
        flops=197e12,          # exactly 1 second of one chip
        hbm_bytes=819e9,       # exactly 1 second of HBM
        coll_bytes=25e9,       # 0.5 s at 50 GB/s
        coll_by_kind={}, mem_args=0, mem_temp=0, mem_output=0,
        peak_memory=0, raw_flops=197e12)
    roof = AN.roofline_terms(cost, chips=256, model_flops=256 * 197e12)
    assert roof.compute_s == pytest.approx(1.0)
    assert roof.memory_s == pytest.approx(1.0)
    assert roof.collective_s == pytest.approx(0.5)
    assert roof.model_flops_ratio == pytest.approx(1.0)
    assert roof.dominant in ("compute", "memory")


def test_calibration_adjustment():
    # flops(L) = fixed + L·per_layer ⇒ analyze with calibration matches
    cost = AN.CellCost
    import types
    class FakeCompiled:
        def cost_analysis(self):
            return {"flops": 100.0, "bytes accessed": 1000.0}
        def as_text(self):
            return "ENTRY %main () -> f32[] { %r = f32[1]{0} add(%a,%b) }"
        def memory_analysis(self):
            return types.SimpleNamespace(argument_size_in_bytes=1,
                                         temp_size_in_bytes=2,
                                         output_size_in_bytes=3,
                                         generated_code_size_in_bytes=0)
    c = AN.analyze_compiled(FakeCompiled(), trip_count=48,
                            calibration=(10.0, 100.0, 5.0))
    assert c.flops == pytest.approx(100.0 + 47 * 10.0)
    assert c.hbm_bytes == pytest.approx(1000.0 + 47 * 100.0)
    assert "calibrated" in c.adjust_note


def test_elastic_and_debug_mesh():
    from repro.launch import mesh as ML
    m = ML.make_debug_mesh((1, 1), ("data", "model"))
    assert m.shape == {"data": 1, "model": 1}
    with pytest.raises(RuntimeError):
        ML.make_production_mesh()      # only 1 CPU device in tests
