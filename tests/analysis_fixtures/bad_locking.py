"""Seeded violations for the lock-order pass (LK7xx).

Each MARK comment pins the line a diagnostic must fire on; the fixture
is parsed (never imported) by tests/test_analysis.py.
"""
import queue
import threading


class DeadlockProne:
    """LK701: `ab` takes _a then _b, `ba` takes them in the opposite
    order — a cycle in the lock-order graph."""

    def __init__(self):
        self._a = threading.Lock()
        self._b = threading.Lock()

    def ab(self):
        with self._a:
            with self._b:  # MARK:LK701a
                pass

    def ba(self):
        with self._b:
            with self._a:  # MARK:LK701b
                pass


class LeakyAcquire:
    """LK702: bare acquire() with no try/finally release."""

    def __init__(self):
        self._lock = threading.Lock()
        self.n = 0

    def bump(self):
        self._lock.acquire()  # MARK:LK702
        self.n += 1
        self._lock.release()


class BlockingUnderLock:
    """LK703: blocking calls made while holding a lock."""

    def __init__(self):
        self._m = threading.Lock()
        self._q = queue.Queue()

    def wait_result(self, fut):
        with self._m:
            return fut.result()  # MARK:LK703a

    def drain_locked(self):
        with self._m:
            return self._q.get()  # MARK:LK703b
