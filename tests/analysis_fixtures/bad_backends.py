"""Seeded jit-static-contract / retrace violations (analyzer fixtures).

Imported by ``tests/test_analysis.py`` and handed to the analyzer's
injection points — ``static_contract.run(registry=...)`` for the SC
classes, a scoped ``core.backend.register`` + ``retrace.run(names=...)``
for the RT classes.  Never part of the real registry.
"""
import dataclasses
from typing import Any, ClassVar

import numpy as np

import jax.numpy as jnp

from repro.core.backend import IVFBackend, RetrievalBackend


# ---------------------------------------------------------------------------
# static-contract violations (checked without ever tracing them)
# ---------------------------------------------------------------------------


@dataclasses.dataclass  # not frozen -> SC201
class UnfrozenBackend(RetrievalBackend):
    name: ClassVar[str] = "fx_unfrozen"
    index_kwarg: ClassVar[str] = "ivf_index"
    h: int = 4


class _StubPlainBatch:
    """Satisfies the `plain_batch` surface so only the seeded defect
    of each class below is reported."""

    def plain_batch(self, index, q, *, k):
        raise NotImplementedError


@dataclasses.dataclass(frozen=True, eq=False)  # identity hash -> SC202
class IdentityHashBackend(_StubPlainBatch, RetrievalBackend):
    name: ClassVar[str] = "fx_identity"
    index_kwarg: ClassVar[str] = "ivf_index"
    stateful: ClassVar[bool] = False


@dataclasses.dataclass(frozen=True)  # array-valued field -> SC203
class ArrayFieldBackend(_StubPlainBatch, RetrievalBackend):
    name: ClassVar[str] = "fx_array"
    index_kwarg: ClassVar[str] = "ivf_index"
    stateful: ClassVar[bool] = False
    boost: Any = dataclasses.field(
        default_factory=lambda: np.ones(3, np.float32),
        hash=False, compare=False)


@dataclasses.dataclass(frozen=True)  # no stateful surface -> SC204
class MissingSurfaceBackend(RetrievalBackend):
    name: ClassVar[str] = "fx_missing"
    index_kwarg: ClassVar[str] = "ivf_index"


@dataclasses.dataclass(frozen=True)  # required knob -> SC205
class NoDefaultBackend(RetrievalBackend):
    name: ClassVar[str] = "fx_nodefault"
    index_kwarg: ClassVar[str] = "ivf_index"
    stateful: ClassVar[bool] = False
    h: int


# ---------------------------------------------------------------------------
# retrace / promotion violations (traced abstractly on the tiny index)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DtypeDriftBackend(IVFBackend):
    """Sequential path downcasts scores -> RT302 (engine drift)."""

    name: ClassVar[str] = "fx_drift"

    def plain(self, index, q, *, k):
        v, i, st = super().plain(index, q, k=k)
        return v.astype(jnp.bfloat16), i, st


@dataclasses.dataclass(frozen=True)
class WeakTypeBackend(IVFBackend):
    """``plain_batch`` emits a weak-typed score leaf -> RT303."""

    name: ClassVar[str] = "fx_weak"

    def plain_batch(self, index, q, *, k):
        v, i, st = super().plain_batch(index, q, k=k)
        return jnp.broadcast_to(jnp.asarray(0.0), v.shape), i, st


@dataclasses.dataclass(frozen=True, eq=False)
class CacheChurnBackend(IVFBackend):
    """Identity-hashed: every fresh instance retraces -> RT301.

    ``eq=False`` alone would *inherit* IVFBackend's value-based
    ``__eq__``/``__hash__``; the explicit identity pair below is what
    actually churns the jit cache key per instance.
    """

    name: ClassVar[str] = "fx_churn"

    def __hash__(self):
        return id(self)

    def __eq__(self, other):
        return self is other
