"""Seeded Pallas BlockSpec violations (kernel-budget fixtures).

Each probe is handed to ``kernel_budget.run(probes=[(label, thunk)])``;
the thunks run under the pass's ``pallas_call`` recorder, so nothing is
lowered or executed — only the declared grid/BlockSpecs are inspected.
"""
import jax
import jax.numpy as jnp


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def overbudget_probe():
    """(1, 4096, 1024) f32 blocks: 16 MiB each, double-buffered in+out
    puts 64 MiB in flight -> PK401."""
    import jax.experimental.pallas as pl

    shape = (8, 4096, 1024)

    def call(x):
        return pl.pallas_call(
            _copy_kernel,
            grid=(8,),
            in_specs=[pl.BlockSpec((1, 4096, 1024),
                                   lambda i: (i, 0, 0))],
            out_specs=pl.BlockSpec((1, 4096, 1024),
                                   lambda i: (i, 0, 0)),
            out_shape=jax.ShapeDtypeStruct(shape, jnp.float32),
        )(x)

    return jax.eval_shape(call, jax.ShapeDtypeStruct(shape, jnp.float32))


def misaligned_probe():
    """Splits the 96-wide lane dim into 48-wide tiles (f32 wants
    multiples of 128 on the last axis) -> PK402."""
    import jax.experimental.pallas as pl

    shape = (512, 96)

    def call(x):
        return pl.pallas_call(
            _copy_kernel,
            grid=(64, 2),
            in_specs=[pl.BlockSpec((8, 48), lambda i, j: (i, j))],
            out_specs=pl.BlockSpec((8, 48), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct(shape, jnp.float32),
        )(x)

    return jax.eval_shape(call, jax.ShapeDtypeStruct(shape, jnp.float32))
