"""Seeded trace-safety violations — analyzer fixture, never imported.

Fed to ``trace_safety.run(modules=modules_from_paths([...]))`` by
``tests/test_analysis.py``; each marked line must fire exactly its
marked diagnostic, and the host-only tail must stay silent.
"""
import functools
import time

import numpy as np

import jax
import jax.numpy as jnp


def _np_helper(x):
    return np.sum(x)  # MARK:TS103


@jax.jit
def branchy(x):
    if x > 0:  # MARK:TS101a
        x = -x
    while jnp.any(x > 0):  # MARK:TS101b
        x = x - 1
    return x


@functools.partial(jax.jit, static_argnames=("k",))
def casty(x, k):
    if k > 1:  # static arg: must NOT fire
        x = x * k
    if x.ndim == 2:  # shape attribute: must NOT fire
        x = x[0]
    s = float(jnp.max(x))  # MARK:TS102
    t = time.time()  # MARK:TS104
    return x * s + t + _np_helper(x)


def host_only(x):
    # unreachable from any jit boundary: nothing below may fire
    if x.shape[0] > 2:
        return np.asarray(x)
    return float(np.sum(x))
