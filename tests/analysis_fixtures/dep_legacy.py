"""Seeded deprecated-alias uses — analyzer fixture, never imported.

Fed to ``deprecation.run(modules=modules_from_paths([...]))``; both the
from-import and the attribute call must be flagged DA601.
"""
from repro.core import toploc
from repro.core.toploc import ivf_start  # MARK:DA601-import


def run_legacy(ivf_index, q0):
    v, i, sess, stats = toploc.ivf_start(ivf_index, q0, k=8)  # MARK:DA601-call
    return ivf_start, v, i, sess, stats


def fine(ivf_index, q0):
    # registry-API call: must NOT fire
    from repro.core import backend
    be = backend.make("ivf", h=8, nprobe=4)
    return be.plain(ivf_index, q0, k=8)
