"""Seeded violations for the guarded-fields pass (GF8xx).

Each MARK comment pins the line a diagnostic must fire on; the fixture
is parsed (never imported) by tests/test_analysis.py.
"""
import threading

from repro.concurrency import guarded_by, holds


@guarded_by("_lock", "count")
class SloppyCounter:
    """`count` is declared guarded by `_lock` but touched bare, and
    `other` is mutated from two methods with no declared guard."""

    def __init__(self):
        self._lock = threading.Lock()
        self.count = 0
        self.other = []

    def bump(self):
        # correct discipline — must NOT be flagged
        with self._lock:
            self.count += 1

    @holds("_lock")
    def bump_locked(self):
        # caller holds the lock by contract — must NOT be flagged
        self.count += 1

    def peek(self):
        return self.count  # MARK:GF801-read

    def reset(self):
        self.count = 0  # MARK:GF801-write

    def push(self, x):
        self.other.append(x)  # MARK:GF802

    def drop(self):
        return self.other.pop()
