"""Seeded shard_map placement violations (shard-spec fixtures).

Imported by ``tests/test_analysis.py`` and handed to
``shard_specs.run(registry=...)`` as replacement entries for the real
``"ivf"`` backend — each entry trips exactly one SS diagnostic.
"""
import dataclasses
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

import jax.numpy as jnp

from repro import compat
from repro.distributed.retrieval import ShardedIVFScan, shard_ivf_index


@dataclasses.dataclass(frozen=True)
class MisdeclaredIVFScan:
    """Declares the partition-sharded posting lists as *replicated*
    ``in_specs`` — contradicts the placement ``shard_ivf_index``
    applies, so every call would pay a silent reshard -> SS501."""

    mesh: Any
    axis: str = "model"

    def __call__(self, index, queries, sel, k):
        def local(lv, li, ls, q, s):
            b = q.shape[0]
            return (jnp.zeros((b, k), jnp.float32),
                    jnp.zeros((b, k), jnp.int32),
                    jnp.zeros((b,), jnp.int32))

        fn = compat.shard_map(
            local, mesh=self.mesh,
            in_specs=(P(None, None, None),          # lists: misdeclared
                      P(self.axis, None), P(self.axis),
                      P(None, None), P(None, None)),
            out_specs=(P(None, None), P(None, None), P(None)),
            check_vma=False)
        return fn(index.list_vecs, index.list_ids, index.list_sizes,
                  queries, sel)


def shard_ivf_index_partition_centroids(mesh, index, *, axis="model"):
    """Partitions the coarse centroids — replicated TopLoc state must
    never shard -> SS502."""
    idx = shard_ivf_index(mesh, index, axis=axis)
    cent = jax.device_put(index.centroids,
                          NamedSharding(mesh, P(axis, None)))
    return idx._replace(centroids=cent)


class MutableIVFScan:
    """Plain mutable class, not a frozen dataclass — cannot ride
    through jit as a static backend field -> SS503."""

    def __init__(self, mesh, axis="model"):
        self.mesh = mesh
        self.axis = axis

    def __call__(self, index, queries, sel, k):
        # delegate to the real plugin so only the SS503 shape is seeded
        return ShardedIVFScan(self.mesh, self.axis)(index, queries,
                                                    sel, k)
