"""Hypothesis property tests on system invariants.

``hypothesis`` is an optional dev dependency (requirements-dev.txt);
the whole module skips cleanly when it is absent so tier-1 collection
never dies on a missing extra.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import ivf, topk
from repro.kernels import ops, ref, sorting

SET = settings(max_examples=25, deadline=None)


@SET
@given(st.integers(1, 6).map(lambda e: 2 ** e),
       st.integers(0, 2 ** 31 - 1))
def test_bitonic_equals_sort(n, seed):
    rng = np.random.default_rng(seed)
    v = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    i = jnp.arange(n, dtype=jnp.int32)
    sv, _ = sorting.bitonic_sort_desc(v, i)
    np.testing.assert_allclose(np.asarray(sv),
                               -np.sort(-np.asarray(v)))


@SET
@given(st.integers(2, 64), st.integers(1, 16), st.integers(0, 2 ** 31 - 1))
def test_topk_subset_dominance(n, k, seed):
    """Scores of top-k over a superset dominate those over a subset."""
    k = min(k, n // 2) or 1
    rng = np.random.default_rng(seed)
    scores = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    v_full, _ = topk.topk(scores, k)
    v_half, _ = topk.topk(scores[: n // 2], min(k, n // 2))
    m = min(k, n // 2)
    assert np.all(np.asarray(v_full[:m]) >= np.asarray(v_half[:m]) - 1e-6)


@SET
@given(st.integers(1, 10), st.integers(1, 10), st.integers(0, 2 ** 31 - 1))
def test_merge_topk_equals_concat_topk(ka, kb, seed):
    rng = np.random.default_rng(seed)
    k = min(ka + kb, 8)
    va = -np.sort(-rng.normal(size=ka).astype(np.float32))
    vb = -np.sort(-rng.normal(size=kb).astype(np.float32))
    ia = np.arange(ka, dtype=np.int32)
    ib = np.arange(100, 100 + kb, dtype=np.int32)
    mv, mi = topk.merge_topk(jnp.asarray(va), jnp.asarray(ia),
                             jnp.asarray(vb), jnp.asarray(ib), k)
    expect = -np.sort(-np.concatenate([va, vb]))[:k]
    np.testing.assert_allclose(np.asarray(mv), expect, rtol=1e-6)


@SET
@given(st.integers(4, 40), st.integers(4, 40), st.integers(0, 2 ** 31 - 1))
def test_intersect_count_vs_python(na, nb, seed):
    rng = np.random.default_rng(seed)
    a = rng.choice(100, na, replace=False).astype(np.int32)
    b = rng.choice(100, nb, replace=False).astype(np.int32)
    got = int(topk.intersect_count(jnp.asarray(a), jnp.asarray(b)))
    assert got == len(set(a.tolist()) & set(b.tolist()))


@SET
@given(st.integers(20, 200), st.integers(2, 8), st.integers(0, 2 ** 31 - 1))
def test_ivf_exactness_full_probe(n, p, seed):
    """Property: IVF with nprobe == p is exhaustive search, any corpus."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, 8)).astype(np.float32)
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    idx = ivf.build(jnp.asarray(x), p=p, iters=3,
                    key=jax.random.PRNGKey(seed % 1000))
    q = jnp.asarray(x[:2] + 0.01)
    ev, ei = ivf.exact_search(jnp.asarray(x), q, 5)
    _, si, _ = ivf.search(idx, q, nprobe=p, k=5)
    np.testing.assert_array_equal(np.asarray(si), np.asarray(ei))


@SET
@given(st.integers(1, 64), st.integers(1, 8), st.integers(0, 2 ** 31 - 1))
def test_embedding_bag_linearity(bag, d, seed):
    """bag(w1+w2) == bag(w1) + bag(w2) (linearity in weights)."""
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.normal(size=(50, d)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, 50, (2, bag)).astype(np.int32))
    w1 = jnp.asarray(rng.uniform(0, 1, (2, bag)).astype(np.float32))
    w2 = jnp.asarray(rng.uniform(0, 1, (2, bag)).astype(np.float32))
    lhs = ref.embedding_bag(table, ids, w1 + w2)
    rhs = ref.embedding_bag(table, ids, w1) + ref.embedding_bag(table, ids,
                                                                w2)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-4,
                               atol=1e-5)


@SET
@given(st.integers(8, 64), st.integers(0, 2 ** 31 - 1))
def test_streaming_topk_equals_topk(n, seed):
    rng = np.random.default_rng(seed)
    scores = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    k = min(5, n)
    v1, i1 = topk.streaming_topk(scores, k, block=8)
    v2, i2 = jax.lax.top_k(scores, k)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-6)


@SET
@given(st.floats(0.0, 1.0), st.integers(0, 2 ** 31 - 1))
def test_softmax_attention_rowstochastic(frac, seed):
    """Attention output is a convex combination of values: bounded by
    min/max of v along the sequence."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(1, 1, 8, 4)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 1, 8, 4)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 1, 8, 4)).astype(np.float32))
    o = np.asarray(ref.mha_attention(q, k, v, causal=False))
    vmin, vmax = np.asarray(v).min(axis=2), np.asarray(v).max(axis=2)
    assert np.all(o <= vmax[:, :, None] + 1e-5)
    assert np.all(o >= vmin[:, :, None] - 1e-5)
