"""Hypothesis property tests on system invariants.

``hypothesis`` is an optional dev dependency (requirements-dev.txt);
the whole module skips cleanly when it is absent so tier-1 collection
never dies on a missing extra.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import ivf, pq, topk, toploc
from repro.kernels import ops, ref, sorting

SET = settings(max_examples=25, deadline=None)


@SET
@given(st.integers(1, 6).map(lambda e: 2 ** e),
       st.integers(0, 2 ** 31 - 1))
def test_bitonic_equals_sort(n, seed):
    rng = np.random.default_rng(seed)
    v = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    i = jnp.arange(n, dtype=jnp.int32)
    sv, _ = sorting.bitonic_sort_desc(v, i)
    np.testing.assert_allclose(np.asarray(sv),
                               -np.sort(-np.asarray(v)))


@SET
@given(st.integers(2, 64), st.integers(1, 16), st.integers(0, 2 ** 31 - 1))
def test_topk_subset_dominance(n, k, seed):
    """Scores of top-k over a superset dominate those over a subset."""
    k = min(k, n // 2) or 1
    rng = np.random.default_rng(seed)
    scores = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    v_full, _ = topk.topk(scores, k)
    v_half, _ = topk.topk(scores[: n // 2], min(k, n // 2))
    m = min(k, n // 2)
    assert np.all(np.asarray(v_full[:m]) >= np.asarray(v_half[:m]) - 1e-6)


@SET
@given(st.integers(1, 10), st.integers(1, 10), st.integers(0, 2 ** 31 - 1))
def test_merge_topk_equals_concat_topk(ka, kb, seed):
    rng = np.random.default_rng(seed)
    k = min(ka + kb, 8)
    va = -np.sort(-rng.normal(size=ka).astype(np.float32))
    vb = -np.sort(-rng.normal(size=kb).astype(np.float32))
    ia = np.arange(ka, dtype=np.int32)
    ib = np.arange(100, 100 + kb, dtype=np.int32)
    mv, mi = topk.merge_topk(jnp.asarray(va), jnp.asarray(ia),
                             jnp.asarray(vb), jnp.asarray(ib), k)
    expect = -np.sort(-np.concatenate([va, vb]))[:k]
    np.testing.assert_allclose(np.asarray(mv), expect, rtol=1e-6)


@SET
@given(st.integers(4, 40), st.integers(4, 40), st.integers(0, 2 ** 31 - 1))
def test_intersect_count_vs_python(na, nb, seed):
    rng = np.random.default_rng(seed)
    a = rng.choice(100, na, replace=False).astype(np.int32)
    b = rng.choice(100, nb, replace=False).astype(np.int32)
    got = int(topk.intersect_count(jnp.asarray(a), jnp.asarray(b)))
    assert got == len(set(a.tolist()) & set(b.tolist()))


@SET
@given(st.integers(20, 200), st.integers(2, 8), st.integers(0, 2 ** 31 - 1))
def test_ivf_exactness_full_probe(n, p, seed):
    """Property: IVF with nprobe == p is exhaustive search, any corpus."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, 8)).astype(np.float32)
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    idx = ivf.build(jnp.asarray(x), p=p, iters=3,
                    key=jax.random.PRNGKey(seed % 1000))
    q = jnp.asarray(x[:2] + 0.01)
    ev, ei = ivf.exact_search(jnp.asarray(x), q, 5)
    _, si, _ = ivf.search(idx, q, nprobe=p, k=5)
    np.testing.assert_array_equal(np.asarray(si), np.asarray(ei))


@SET
@given(st.integers(1, 64), st.integers(1, 8), st.integers(0, 2 ** 31 - 1))
def test_embedding_bag_linearity(bag, d, seed):
    """bag(w1+w2) == bag(w1) + bag(w2) (linearity in weights)."""
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.normal(size=(50, d)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, 50, (2, bag)).astype(np.int32))
    w1 = jnp.asarray(rng.uniform(0, 1, (2, bag)).astype(np.float32))
    w2 = jnp.asarray(rng.uniform(0, 1, (2, bag)).astype(np.float32))
    lhs = ref.embedding_bag(table, ids, w1 + w2)
    rhs = ref.embedding_bag(table, ids, w1) + ref.embedding_bag(table, ids,
                                                                w2)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-4,
                               atol=1e-5)


@SET
@given(st.integers(1, 3),                       # m = 2^e subquantizers
       st.sampled_from([16, 64, 256]),          # codebook size
       st.integers(4, 24),                      # p partitions
       st.integers(3, 80),                      # Lmax (incl. non-pow2)
       st.integers(1, 4),                       # nprobe
       st.integers(1, 16),                      # k
       st.integers(0, 2 ** 31 - 1))
def test_pq_adc_kernel_matches_reference(me, ncodes, p, lmax, npb, k,
                                         seed):
    """The Pallas ADC kernel (interpret mode) agrees with the pure-jnp
    ``pq.adc_table``/``adc_scores`` semantics for any dims/m/list
    lengths.  Values must match within float tolerance; returned ids
    must carry exactly their reference ADC score (robust to ties from
    duplicate code rows)."""
    m = 2 ** me
    npb = min(npb, p)
    k = min(k, npb * lmax)
    rng = np.random.default_rng(seed)
    tables = jnp.asarray(rng.normal(size=(2, m, ncodes))
                         .astype(np.float32))
    codes = jnp.asarray(rng.integers(0, ncodes, (p, lmax, m))
                        .astype(np.uint8))
    ids = rng.integers(0, 10 ** 6, (p, lmax)).astype(np.int32)
    ids[rng.uniform(size=(p, lmax)) < 0.25] = -1
    ids = jnp.asarray(ids)
    sel = jnp.asarray(np.stack(
        [rng.permutation(p)[:npb] for _ in range(2)]).astype(np.int32))
    v, i = ops.pq_adc_scan(tables, codes, ids, sel, k, mode="interpret")
    rv, ri = ref.pq_adc_scan_batch(tables, codes, ids, sel, k)
    np.testing.assert_allclose(np.asarray(v), np.asarray(rv), rtol=1e-5,
                               atol=1e-5)
    # id-level check via scores (ties may legally reorder): every
    # returned id's ADC score — computed through the independent
    # pq.adc_scores reference — equals the returned value
    flat_codes = np.asarray(codes).reshape(-1, m)
    flat_ids = np.asarray(ids).reshape(-1)
    for row in range(2):
        book_scores = np.asarray(pq.adc_scores(
            tables[row], jnp.asarray(flat_codes)))
        for val, doc in zip(np.asarray(v[row]), np.asarray(i[row])):
            if doc < 0:
                assert val == -np.inf
                continue
            cand = book_scores[flat_ids == doc]
            assert np.any(np.abs(cand - val) < 1e-4), (doc, val, cand)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1), nprobe=st.integers(1, 4),
       rerank=st.integers(8, 48))
def test_ivf_pq_topk_subset_of_candidates(ivf_pq_index, seed, nprobe,
                                          rerank):
    """For every generated query, the exact-re-ranked TopLoc_IVFPQ top-k
    is a subset of the PQ (ADC) candidate set it was re-ranked from."""
    idx = ivf_pq_index
    rng = np.random.default_rng(seed)
    q = rng.normal(size=(idx.d,)).astype(np.float32)
    q = jnp.asarray(q / max(np.linalg.norm(q), 1e-9))
    rerank = max(rerank, 10)
    cache_ids, _ = ivf.make_cache(idx, q, h=16)
    sel = cache_ids[:nprobe]
    tables = toploc._adc_tables(idx, q[None])
    _, cand = ops.pq_adc_scan(tables, idx.list_codes, idx.list_ids,
                              sel[None], max(10, min(rerank,
                                                     nprobe * idx.lmax)))
    from repro.core.backend import IVFPQBackend
    v, i, _, _ = toploc.start(IVFPQBackend(h=16, nprobe=nprobe,
                                           rerank=rerank), idx, q, k=10)
    returned = set(np.asarray(i).tolist()) - {-1}
    assert returned <= set(np.asarray(cand[0]).tolist()), (
        returned - set(np.asarray(cand[0]).tolist()))


@SET
@given(st.integers(8, 64), st.integers(0, 2 ** 31 - 1))
def test_streaming_topk_equals_topk(n, seed):
    rng = np.random.default_rng(seed)
    scores = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    k = min(5, n)
    v1, i1 = topk.streaming_topk(scores, k, block=8)
    v2, i2 = jax.lax.top_k(scores, k)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-6)


@SET
@given(st.floats(0.0, 1.0), st.integers(0, 2 ** 31 - 1))
def test_softmax_attention_rowstochastic(frac, seed):
    """Attention output is a convex combination of values: bounded by
    min/max of v along the sequence."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(1, 1, 8, 4)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 1, 8, 4)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 1, 8, 4)).astype(np.float32))
    o = np.asarray(ref.mha_attention(q, k, v, causal=False))
    vmin, vmax = np.asarray(v).min(axis=2), np.asarray(v).max(axis=2)
    assert np.all(o <= vmax[:, :, None] + 1e-5)
    assert np.all(o >= vmin[:, :, None] - 1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 9), st.integers(1, 12), st.integers(1, 4),
       st.integers(1, 6), st.integers(1, 24),
       st.integers(0, 2 ** 31 - 1))
def test_fused_turn_matches_three_dispatch(p, lmax, b, nprobe, k, seed):
    """The fused single-dispatch turn (ref oracle) is bit-identical —
    values AND ids — to the classic 3-dispatch composition: centroid
    top-nprobe, gather-scan, flat top-k.  Small integer-valued vectors
    force abundant exact score ties, so id equality pins the tie-break
    to the flat candidate order the staged path uses; ragged lists
    (including empty ones) exercise the padding masks."""
    nprobe = min(nprobe, p)
    k = min(k, nprobe * lmax)
    d = 8
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.integers(-2, 3, size=(b, d)).astype(np.float32))
    cents = jnp.asarray(rng.integers(-2, 3, size=(p, d))
                        .astype(np.float32))
    lv = rng.integers(-2, 3, size=(p, lmax, d)).astype(np.float32)
    li = np.full((p, lmax), -1, np.int32)
    sizes = rng.integers(0, lmax + 1, size=p)
    nid = 0
    for pi in range(p):
        for l in range(sizes[pi]):
            li[pi, l] = nid
            nid += 1
        lv[pi, sizes[pi]:] = 0
    lv, li = jnp.asarray(lv), jnp.asarray(li)

    fv, fi, fsel = ops.fused_turn(q, cents, lv, li, nprobe=nprobe, k=k,
                                  mode="ref")

    # classic 3-dispatch: the exact production formulation
    cs = toploc._bcast_centroid_scores(cents, q)
    _, sel = jax.lax.top_k(cs, nprobe)
    scores = jnp.einsum("bd,bnld->bnl", q, lv[sel])
    scores = jnp.where(li[sel] >= 0, scores, -jnp.inf)
    v3, pos = jax.lax.top_k(scores.reshape(b, -1), k)
    i3 = jnp.take_along_axis(li[sel].reshape(b, -1), pos, axis=-1)
    i3 = jnp.where(jnp.isfinite(v3), i3, -1)

    np.testing.assert_array_equal(np.asarray(fsel), np.asarray(sel))
    np.testing.assert_array_equal(np.asarray(fv), np.asarray(v3))
    np.testing.assert_array_equal(np.asarray(fi), np.asarray(i3))


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 8), st.integers(1, 10), st.integers(1, 3),
       st.integers(1, 5), st.integers(0, 2 ** 31 - 1))
def test_fused_scan_pos_is_distributed_tiebreak(p, lmax, b, nprobe,
                                                seed):
    """``fused_scan``'s returned positions are the flat candidate
    indices ``distributed_topk_ordered`` sorts by — so a lexicographic
    (score desc, pos asc) merge of its candidates reproduces the dense
    flat top-k exactly, even under duplicate scores.  This is the
    invariant that makes the sharded fused path bit-identical to the
    single-device turn."""
    nprobe = min(nprobe, p)
    k = min(4, nprobe * lmax)
    d = 8
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.integers(-2, 3, size=(b, d)).astype(np.float32))
    lv = rng.integers(-2, 3, size=(p, lmax, d)).astype(np.float32)
    li = np.full((p, lmax), -1, np.int32)
    sizes = rng.integers(0, lmax + 1, size=p)
    nid = 0
    for pi in range(p):
        for l in range(sizes[pi]):
            li[pi, l] = nid
            nid += 1
        lv[pi, sizes[pi]:] = 0
    lv, li = jnp.asarray(lv), jnp.asarray(li)
    sel = jnp.asarray(np.stack([rng.permutation(p)[:nprobe]
                                for _ in range(b)]).astype(np.int32))

    cv, ci, cpos = ops.fused_scan(q, lv, li, sel, k, mode="ref")

    # dense oracle over the same probe set
    scores = jnp.einsum("bd,bnld->bnl", q, lv[sel])
    scores = jnp.where(li[sel] >= 0, scores, -jnp.inf)
    dv, dpos = jax.lax.top_k(scores.reshape(b, -1), k)
    di = jnp.take_along_axis(li[sel].reshape(b, -1), dpos, axis=-1)

    # (score desc, pos asc) merge — distributed_topk_ordered's sort key
    _, _, mi, mv = jax.lax.sort((-cv, cpos, ci, cv), dimension=-1,
                                num_keys=2)
    mv, mi = mv[:, :k], mi[:, :k]
    np.testing.assert_array_equal(np.asarray(mv), np.asarray(dv))
    fin = np.isfinite(np.asarray(dv))
    np.testing.assert_array_equal(np.asarray(mi)[fin],
                                  np.asarray(di)[fin])
