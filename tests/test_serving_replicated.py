"""Replica-routed serving: affinity, load balance, hedging, identity.

The contract under test (serving/router.py + distributed/retrieval.py):
routing conversations over R replica engines — each with its own
session slab, result cache, and (optionally) corpus submesh — is
bit-identical per session to a single engine serving that conversation,
because stateful traffic is pinned to one replica for its lifetime and
stateless traffic is identical on every replica by the replication
contract.  Mesh construction tests follow the device-count gating
pattern of test_sharded_retrieval.py: they run fully on a 1-device
host and exercise real 2-D meshes under the CI 8-device job.
"""
import threading
import time

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.distributed import retrieval as R
from repro.serving import (ConversationalSearchEngine,
                           ReplicatedSearchEngine, ServingConfig)

K, H, NPROBE = 10, 16, 4
T = 4


def _cfg(strategy="toploc+", **kw):
    return ServingConfig(backend="ivf", strategy=strategy, nprobe=NPROBE,
                         h=H, alpha=0.3, k=K, **kw)


def _router(ivf_index, *, replicas=2, strategy="toploc+", n_slots=8,
            **kw):
    return ReplicatedSearchEngine(
        _cfg(strategy=strategy), replicas=replicas, ivf_index=ivf_index,
        n_slots=n_slots, max_batch=4, max_wait_s=1e-4, **kw)


# ------------------------------------------------------------------ mesh

def test_retrieval_mesh_replicas_1_stays_1d():
    mesh = R.retrieval_mesh(1, replicas=1)
    assert mesh.devices.ndim == 1 and mesh.axis_names == ("model",)


@pytest.mark.skipif(jax.device_count() < 4,
                    reason="2-D mesh needs >= 4 devices")
def test_retrieval_mesh_2d_shape_and_axis_names():
    mesh = R.retrieval_mesh(2, replicas=2)
    assert mesh.devices.shape == (2, 2)
    assert mesh.axis_names == ("replica", "model")


def test_retrieval_mesh_too_few_devices_raises():
    with pytest.raises(ValueError, match="device"):
        R.retrieval_mesh(jax.device_count(), replicas=2)


def test_replica_submeshes_1d_passthrough():
    mesh = R.retrieval_mesh(1)
    assert R.replica_submeshes(mesh) == [mesh]


@pytest.mark.skipif(jax.device_count() < 4,
                    reason="2-D mesh needs >= 4 devices")
def test_replica_submeshes_split_is_disjoint_and_covering():
    mesh = R.retrieval_mesh(2, replicas=2)
    subs = R.replica_submeshes(mesh)
    assert len(subs) == 2
    seen = set()
    for sm in subs:
        assert sm.axis_names == ("model",)
        assert sm.devices.shape == (2,)
        seen.update(d.id for d in sm.devices.flat)
    assert seen == {d.id for d in mesh.devices.flat}


# -------------------------------------------------------------- routing

def test_session_affinity_sticky_across_turns(small_corpus, ivf_index):
    wl = small_corpus
    with _router(ivf_index) as eng:
        for t in range(T):
            eng.query("c0", jnp.asarray(wl.conversations[0, t]))
        eng.drain()
        r = eng.replica_of("c0")
        assert r is not None
        # every turn landed on the pinned replica
        assert all(rec.conv_id != "c0" or True for rec in eng.records)
        assert [rec.conv_id for rec in eng.engines[r].records].count("c0") \
            == T
        other = eng.engines[1 - r]
        assert all(rec.conv_id != "c0" for rec in other.records)


def test_affinity_survives_slab_eviction(small_corpus, ivf_index):
    """An LRU eviction inside a replica's slab does NOT unpin: the
    conversation rebuilds on the same replica (single-engine eviction
    semantics), so routed results keep matching a single engine."""
    wl = small_corpus
    # n_slots=4 == max_batch floor; pin 5 convs to thrash one replica
    with _router(ivf_index, n_slots=4) as eng:
        eng.query("a", jnp.asarray(wl.conversations[0, 0]))
        r = eng.replica_of("a")
        # fill replica r's slab past capacity with directly-pinned convs
        with eng._route_lock:
            for j in range(4):
                eng._replica_of[f"f{j}"] = r
                eng._load[r] += 1
        for j in range(4):
            eng.query(f"f{j}", jnp.asarray(wl.conversations[1, 0]))
        assert eng.engines[r].store.evictions >= 1
        assert eng.engines[r].store.lookup("a") is None
        # evicted but still pinned; the next turn resumes on replica r
        assert eng.replica_of("a") == r
        eng.query("a", jnp.asarray(wl.conversations[0, 1]))
        assert eng.replica_of("a") == r
        assert eng.engines[r].store.lookup("a") is not None


def test_least_loaded_pinning_spreads_sessions(small_corpus, ivf_index):
    wl = small_corpus
    with _router(ivf_index) as eng:
        for c in range(4):
            eng.query(f"c{c}", jnp.asarray(wl.conversations[c % 3, 0]))
        ls = eng.load_stats()
        assert ls["per_replica_sessions"] == [2, 2]
        assert ls["per_replica_turns"] == [2, 2]
        assert ls["imbalance"] == 1.0
        # end_conversation unpins and frees capacity
        eng.end_conversation("c0")
        assert eng.replica_of("c0") is None
        assert sum(eng.load_stats()["per_replica_sessions"]) == 3


def test_replicas_must_match_prebuilt_mesh(ivf_index):
    mesh = R.retrieval_mesh(1)          # 1-D: one replica group
    cfg = _cfg(mesh=mesh)
    with pytest.raises(ValueError, match="replica"):
        ReplicatedSearchEngine(cfg, replicas=2, ivf_index=ivf_index)


# ------------------------------------------------------------- identity

@pytest.mark.parametrize("cache", [False, True])
def test_routed_matches_sequential_per_session(small_corpus, ivf_index,
                                               cache):
    """R=2 routed serving reproduces the sequential single-engine result
    for every (conversation, turn), result cache off and on."""
    wl = small_corpus
    kw = dict(cache_threshold=0.95, cache_depth=8) if cache else {}
    cfg = ServingConfig(backend="ivf", strategy="toploc+", nprobe=NPROBE,
                        h=H, alpha=0.3, k=K, **kw)
    seq = ConversationalSearchEngine(cfg, ivf_index=ivf_index)
    with ReplicatedSearchEngine(
            cfg, replicas=2, ivf_index=ivf_index, n_slots=8, max_batch=4,
            max_wait_s=1e-4) as eng:
        futs = {}
        for t in range(T):
            for c in range(3):
                qv = jnp.asarray(wl.conversations[c, t])
                futs[(c, t)] = (seq.query(f"c{c}", qv),
                                eng.submit(f"c{c}", qv))
            eng.drain()
        for (c, t), ((sv, si), fut) in futs.items():
            bv, bi = fut.result(timeout=5)
            np.testing.assert_array_equal(si, bi, err_msg=f"c{c} t{t}")
            np.testing.assert_array_equal(sv, bv, err_msg=f"c{c} t{t}")
        assert seq.summary()["refresh_rate"] == eng.summary()["refresh_rate"]
        if cache:
            assert eng.cache_stats()["hits"] >= 0


@pytest.mark.skipif(jax.device_count() < 4,
                    reason="(2 replicas x 2 shards) needs >= 4 devices")
def test_routed_sharded_2d_matches_sequential(small_corpus, ivf_index):
    """Full 2-D mesh: 2 replicas x 2 corpus shards, bit-identical to the
    unsharded sequential engine (replication x sharded-scan contracts
    compose)."""
    wl = small_corpus
    seq = ConversationalSearchEngine(_cfg(), ivf_index=ivf_index)
    with ReplicatedSearchEngine(
            _cfg(shards=2), replicas=2, ivf_index=ivf_index, n_slots=8,
            max_batch=4, max_wait_s=1e-4) as eng:
        assert all(e.mesh is not None for e in eng.engines)
        futs = {}
        for t in range(T):
            for c in range(3):
                qv = jnp.asarray(wl.conversations[c, t])
                futs[(c, t)] = (seq.query(f"c{c}", qv),
                                eng.submit(f"c{c}", qv))
            eng.drain()
        for (c, t), ((sv, si), fut) in futs.items():
            bv, bi = fut.result(timeout=5)
            np.testing.assert_array_equal(si, bi, err_msg=f"c{c} t{t}")
            np.testing.assert_array_equal(sv, bv, err_msg=f"c{c} t{t}")


# -------------------------------------------------------------- hedging

def test_stateless_plain_traffic_is_hedged_and_identical(small_corpus,
                                                         ivf_index):
    """strategy='plain' has no session state: the router hedges across
    replicas and the winner's result is bit-identical to the sequential
    plain engine no matter which replica won."""
    wl = small_corpus
    seq = ConversationalSearchEngine(_cfg(strategy="plain"),
                                     ivf_index=ivf_index)
    with _router(ivf_index, strategy="plain",
                 hedge_floor_s=0.0) as eng:
        assert not eng.stateful
        # slow replica 0's dispatch so hedges actually fire and replica 1
        # wins some requests
        real_flush = eng.engines[0].flush

        def slow_flush():
            time.sleep(0.01)
            return real_flush()
        eng.engines[0].flush = slow_flush
        futs = [(seq.query(f"p{j}", jnp.asarray(wl.conversations[j % 3, 0])),
                 eng.submit(f"p{j}", jnp.asarray(wl.conversations[j % 3, 0])))
                for j in range(8)]
        for (sv, si), fut in futs:
            bv, bi = fut.result(timeout=30)
            np.testing.assert_array_equal(si, bi)
            np.testing.assert_array_equal(sv, bv)
        hs = eng.hedge_stats()
        assert hs["calls"] == 8


def test_hedge_stats_exposed_only_for_stateless(ivf_index):
    with _router(ivf_index) as eng:
        assert eng.stateful and eng.hedge_stats() == {}
    with _router(ivf_index, strategy="plain") as eng2:
        assert not eng2.stateful and "calls" in eng2.hedge_stats()


# ------------------------------------------------------------ lifecycle

def test_router_close_is_idempotent_and_stops_pumps(small_corpus,
                                                    ivf_index):
    wl = small_corpus
    eng = _router(ivf_index)
    eng.start()
    assert [t.is_alive() for t in eng._pumps] == [True, True]
    fut = eng.submit("c0", jnp.asarray(wl.conversations[0, 0]))
    fut.result(timeout=30)          # pump threads serve pinned traffic
    pumps = list(eng._pumps)
    eng.close()
    eng.close()                     # idempotent
    assert not eng._pumps
    assert all(not t.is_alive() for t in pumps)
    assert threading.active_count() < 50


def test_router_use_after_close_raises(small_corpus, ivf_index):
    """Every serving/mutation entry point fails loudly after close()
    instead of hanging on dead pumps or mutating torn-down replicas."""
    wl = small_corpus
    eng = ReplicatedSearchEngine(
        _cfg(segment_cap=8), replicas=2, ivf_index=ivf_index,
        doc_vecs=jnp.asarray(wl.doc_vecs), n_slots=8, max_batch=4,
        max_wait_s=1e-4)
    q = jnp.asarray(wl.conversations[0, 0])
    eng.query("c0", q)
    eng.close()
    with pytest.raises(RuntimeError, match="closed"):
        eng.submit("c0", q)
    with pytest.raises(RuntimeError, match="closed"):
        eng.query("c0", q)
    with pytest.raises(RuntimeError, match="closed"):
        eng.add_documents(np.asarray(wl.doc_vecs[:2]))
    with pytest.raises(RuntimeError, match="closed"):
        eng.delete_documents([0])
    with pytest.raises(RuntimeError, match="closed"):
        eng.compact()


def test_router_replicas_must_be_positive(ivf_index):
    with pytest.raises(ValueError, match="replicas"):
        ReplicatedSearchEngine(_cfg(), replicas=0, ivf_index=ivf_index)


def test_query_survives_racing_end_conversation(small_corpus, ivf_index):
    """Regression: query() used to read ``self._replica_of[conv_id]``
    without the route lock — a concurrent ``end_conversation`` landing
    between submit() and that read KeyError'd the turn.  The pin read
    now goes through ``replica_of()`` and a gone pin falls back to
    draining every replica until the (already enqueued) future lands."""
    wl = small_corpus
    with _router(ivf_index) as eng:
        eng.query("c0", jnp.asarray(wl.conversations[0, 0]))
        assert eng.replica_of("c0") is not None

        orig_submit = eng.submit

        def racing_submit(conv_id, qvec):
            fut = orig_submit(conv_id, qvec)
            # the race, made deterministic: the conversation ends right
            # after its turn is enqueued, before query() reads the pin
            eng.end_conversation(conv_id)
            return fut

        eng.submit = racing_submit
        v, i = eng.query("c0", jnp.asarray(wl.conversations[0, 1]))
        assert v.shape == (K,) and i.shape == (K,)
        assert eng.replica_of("c0") is None


def test_router_broadcast_mutations_keep_replicas_identical(
        small_corpus, ivf_index):
    """add/delete/compact broadcast to every replica; ids agree, the
    epoch advances in lockstep, and a deleted doc is gone from results
    on whichever replica serves the follow-up."""
    wl = small_corpus
    n0 = wl.doc_vecs.shape[0]
    with ReplicatedSearchEngine(
            _cfg(segment_cap=8), replicas=2, ivf_index=ivf_index,
            n_slots=8, max_batch=4, max_wait_s=1e-4) as eng:
        ids = eng.add_documents(wl.doc_vecs[:3] * 0.5)
        assert ids.tolist() == [n0, n0 + 1, n0 + 2]
        assert eng.corpus_epoch == 1
        eng.delete_documents([int(ids[1])])
        assert eng.corpus_epoch == 2
        for c in ("a", "b", "c"):    # spread over both replicas
            _, i = eng.query(c, jnp.asarray(wl.doc_vecs[ids[1] - n0] * 0.5))
            assert int(ids[1]) not in np.asarray(i)
        eng.compact()
        assert eng.corpus_epoch == 3
        for c in ("a", "b"):
            _, i = eng.query(c, jnp.asarray(wl.conversations[1, 1]))
            assert i.shape == (K,)
