"""Per-architecture smoke tests: REDUCED config, one real forward/train
step on CPU, asserting output shapes + no NaNs (the brief's (f))."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get, list_archs
from repro.distributed import sharding as SH

AXES = SH.Axes(data=("data",), model="model")

LM_ARCHS = ["grok-1-314b", "deepseek-v2-lite-16b", "qwen1.5-4b",
            "qwen3-14b", "yi-9b"]


def _materialize(structs, rng, int_hi=8):
    """Concrete arrays from ShapeDtypeStructs (small ints for ids)."""
    def mk(s):
        if jnp.issubdtype(s.dtype, jnp.integer):
            return jnp.asarray(
                rng.integers(0, int_hi, s.shape).astype(np.int32))
        if s.dtype == jnp.bool_:
            return jnp.ones(s.shape, bool)
        return jnp.asarray(rng.normal(size=s.shape).astype(np.float32)
                           ).astype(s.dtype)
    return jax.tree.map(mk, structs)


def test_registry_has_all_ten():
    archs = list_archs()
    for a in LM_ARCHS + ["gin-tu", "two-tower-retrieval", "dcn-v2", "bst",
                         "autoint"]:
        assert a in archs, archs


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_smoke_train_step(arch_id):
    arch = get(arch_id)
    cfg = arch.make_smoke_config()
    bundle = arch.build_bundle(cfg, "train_4k", AXES, n_dp=1, smoke=True,
                               shape_overrides=dict(seq_len=32,
                                                    global_batch=2))
    rng = np.random.default_rng(0)
    from repro.models import transformer as TF
    params = TF.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = _opt_state_like(bundle, params)
    batch = _materialize(bundle.arg_structs[2], rng, int_hi=cfg.vocab)
    params2, opt2, metrics = jax.jit(bundle.step_fn)(params, opt_state,
                                                     batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["gnorm"]))
    for leaf in jax.tree.leaves(params2):
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all())


def _opt_state_like(bundle, params):
    """Re-init optimizer state for concrete params via the bundle's
    struct shapes (step fns close over their optimizer)."""
    structs = bundle.arg_structs[1]
    def mk(s):
        return jnp.zeros(s.shape, s.dtype)
    return jax.tree.map(mk, structs)


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_smoke_decode_step(arch_id):
    arch = get(arch_id)
    cfg = arch.make_smoke_config()
    bundle = arch.build_bundle(cfg, "decode_32k", AXES, n_dp=1, smoke=True,
                               shape_overrides=dict(seq_len=64,
                                                    global_batch=2))
    rng = np.random.default_rng(0)
    from repro.models import transformer as TF
    params = TF.init_params(cfg, jax.random.PRNGKey(0))
    cache = _materialize(bundle.arg_structs[1], rng)
    cache = jax.tree.map(jnp.zeros_like, cache)
    tokens = jnp.asarray([1, 2], jnp.int32)
    cache_len = jnp.asarray([0, 3], jnp.int32)
    logits, new_cache = jax.jit(bundle.step_fn)(params, cache, tokens,
                                                cache_len)
    assert logits.shape == (2, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("shape", ["full_graph_sm", "minibatch_lg",
                                   "ogb_products", "molecule"])
def test_gin_smoke_all_shapes(shape):
    arch = get("gin-tu")
    cfg = arch.make_smoke_config()
    overrides = {}
    if shape in ("full_graph_sm", "ogb_products"):
        overrides = dict(n_nodes=64, n_edges=256, pad_edges_to=64,
                         d_feat=8, n_classes=4)
    elif shape == "minibatch_lg":
        overrides = dict(batch_nodes=8, tree_nodes=10, tree_edges=9,
                         d_feat=8, n_classes=4)
    else:
        overrides = dict(batch=4, n_nodes=6, n_edges=10, d_feat=8,
                         n_classes=2)
    bundle = arch.build_bundle(cfg, shape, AXES, smoke=True,
                               shape_overrides=overrides)
    rng = np.random.default_rng(0)
    from repro.models import gnn
    import dataclasses
    gcfg = dataclasses.replace(cfg, d_in=8,
                               n_classes=overrides.get("n_classes", 4))
    params = gnn.init_params(gcfg, jax.random.PRNGKey(0))
    opt_state = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                             bundle.arg_structs[1])
    batch = _materialize(bundle.arg_structs[2], rng, int_hi=4)
    n_cls = overrides.get("n_classes", 4)
    if "labels" in batch:
        batch["labels"] = batch["labels"] % n_cls
    p2, o2, metrics = jax.jit(bundle.step_fn)(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"])), shape
    for leaf in jax.tree.leaves(p2):
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all())


RECSYS = ["two-tower-retrieval", "dcn-v2", "bst", "autoint"]


@pytest.mark.parametrize("arch_id", RECSYS)
def test_recsys_smoke_train(arch_id):
    arch = get(arch_id)
    cfg = arch.make_smoke_config()
    bundle = arch.build_bundle(cfg, "train_batch", AXES, smoke=True,
                               shape_overrides=dict(batch=16))
    rng = np.random.default_rng(0)
    from repro.models import recsys as R
    init = {"two-tower-retrieval": R.two_tower_init, "dcn-v2": R.dcnv2_init,
            "bst": R.bst_init, "autoint": R.autoint_init}[arch_id]
    params = init(cfg, jax.random.PRNGKey(0))
    opt_state = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                             bundle.arg_structs[1])
    batch = _materialize(bundle.arg_structs[2], rng, int_hi=60)
    p2, o2, metrics = jax.jit(bundle.step_fn)(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"])), arch_id


@pytest.mark.parametrize("arch_id", RECSYS)
def test_recsys_smoke_serve(arch_id):
    arch = get(arch_id)
    cfg = arch.make_smoke_config()
    bundle = arch.build_bundle(cfg, "serve_p99", AXES, smoke=True,
                               shape_overrides=dict(batch=8))
    rng = np.random.default_rng(0)
    from repro.models import recsys as R
    init = {"two-tower-retrieval": R.two_tower_init, "dcn-v2": R.dcnv2_init,
            "bst": R.bst_init, "autoint": R.autoint_init}[arch_id]
    params = init(cfg, jax.random.PRNGKey(0))
    args = [_materialize(s, rng, int_hi=60)
            for s in bundle.arg_structs[1:]]
    out = jax.jit(bundle.step_fn)(params, *args)
    flat = jax.tree.leaves(out)
    assert all(bool(jnp.isfinite(x.astype(jnp.float32)).all())
               for x in flat)
