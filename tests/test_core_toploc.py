"""TopLoc behaviour: the paper's mechanisms (§2) as testable invariants.

Driven through the ``core.backend`` registry API (the legacy prefixed
entry points are pinned against it in tests/test_backend_registry.py).
"""
import numpy as np
import jax.numpy as jnp

from repro.core import ivf, toploc
from repro.core.backend import HNSWBackend, IVFBackend
from repro.core.topk import intersect_count


def test_ivf_start_builds_top_h_cache(ivf_index, small_corpus):
    q0 = jnp.asarray(small_corpus.conversations[0, 0])
    bk = IVFBackend(h=8, nprobe=4)
    _, _, sess, stats = toploc.start(bk, ivf_index, q0, k=10)
    csims = np.asarray(ivf_index.centroids @ q0)
    expect = set(np.argsort(-csims)[:8].tolist())
    assert set(np.asarray(sess.cache_ids).tolist()) == expect
    assert int(stats.centroid_dists) == ivf_index.p     # full scan on turn 0


def test_ivf_step_cached_work(ivf_index, small_corpus):
    conv = jnp.asarray(small_corpus.conversations[0])
    bk = IVFBackend(h=8, nprobe=4, alpha=-1.0)
    _, _, sess, _ = toploc.start(bk, ivf_index, conv[0], k=10)
    _, _, sess, stats = toploc.step(bk, ivf_index, sess, conv[1], k=10)
    assert int(stats.centroid_dists) == 8               # h, not p
    assert not bool(stats.refreshed)


def test_ivf_static_cache_never_refreshes(ivf_index, small_corpus):
    conv = jnp.asarray(small_corpus.conversations[1])
    bk = IVFBackend(h=8, nprobe=4, alpha=-1.0)
    _, _, stats = toploc.conversation(bk, ivf_index, conv, k=10)
    assert not np.any(np.asarray(stats.refreshed)[1:])


def test_ivf_plus_refreshes_on_topic_shift(ivf_index, small_corpus):
    """A hard topic shift must push |I0| below α·np and trigger refresh."""
    d = small_corpus.doc_vecs.shape[1]
    c0 = small_corpus.topic_centers[0]
    c1 = small_corpus.topic_centers[
        np.argmin(small_corpus.topic_centers @ c0)]      # farthest topic
    conv = np.stack([c0, c0, c1, c1]).astype(np.float32)
    bk = IVFBackend(h=8, nprobe=4, alpha=0.5)
    _, _, stats = toploc.conversation(bk, ivf_index, jnp.asarray(conv),
                                      k=10)
    refreshed = np.asarray(stats.refreshed)
    assert refreshed[2] or refreshed[3], (
        f"i0={np.asarray(stats.i0)}, refreshed={refreshed}")
    # and the refresh pays the extra full centroid scan
    cd = np.asarray(stats.centroid_dists)
    ref_turn = 2 if refreshed[2] else 3
    assert cd[ref_turn] == 8 + ivf_index.p


def test_i0_definition_matches_eq1(ivf_index, small_corpus):
    """|I0| = |top_np(qj, C0) ∩ top_np(q0, C0)| computed independently."""
    conv = jnp.asarray(small_corpus.conversations[2])
    h, npb = 8, 4
    bk = IVFBackend(h=h, nprobe=npb, alpha=-1.0)
    _, _, sess, _ = toploc.start(bk, ivf_index, conv[0], k=10)
    _, _, _, stats = toploc.step(bk, ivf_index, sess, conv[1], k=10)
    cache = np.asarray(sess.cache_ids)
    cvecs = np.asarray(ivf_index.centroids)[cache]
    top_qj = cache[np.argsort(-(cvecs @ np.asarray(conv[1])))[:npb]]
    top_q0 = cache[np.argsort(-(cvecs @ np.asarray(conv[0])))[:npb]]
    expect = len(set(top_qj.tolist()) & set(top_q0.tolist()))
    assert int(stats.i0) == expect


def test_toploc_reduces_work_and_holds_recall(ivf_index, small_corpus):
    """The paper's core claim, miniature: much less centroid work at
    comparable effectiveness on topically-local conversations."""
    docs = jnp.asarray(small_corpus.doc_vecs)
    bk = IVFBackend(h=8, nprobe=4, alpha=0.1)
    tot_plain, tot_cached, rec_plain, rec_cached = 0, 0, [], []
    for c in range(small_corpus.conversations.shape[0]):
        conv = jnp.asarray(small_corpus.conversations[c])
        ev, ei = ivf.exact_search(docs, conv, 10)
        v, i, st = toploc.conversation(bk, ivf_index, conv, k=10)
        vp, ip, stp = toploc.conversation(bk, ivf_index, conv, k=10,
                                          mode="plain")
        tot_cached += int(np.asarray(st.centroid_dists).sum())
        tot_plain += int(np.asarray(stp.centroid_dists).sum())
        for t in range(conv.shape[0]):
            gold = set(np.asarray(ei[t]).tolist())
            rec_cached.append(len(set(np.asarray(i[t]).tolist()) & gold))
            rec_plain.append(len(set(np.asarray(ip[t]).tolist()) & gold))
    assert tot_cached < 0.5 * tot_plain          # ≥2x less centroid work
    assert np.mean(rec_cached) >= np.mean(rec_plain) - 1.0


def test_hnsw_entry_point_session(hnsw_index, small_corpus):
    q0 = jnp.asarray(small_corpus.conversations[0, 0])
    bk = HNSWBackend(ef=16, up=2)
    v, i, sess, stats = toploc.start(bk, hnsw_index, q0, k=5)
    assert int(sess.entry_point) == int(i[0])
    q1 = jnp.asarray(small_corpus.conversations[0, 1])
    v2, i2, sess2, stats2 = toploc.step(bk, hnsw_index, sess, q1, k=5)
    assert int(sess2.entry_point) == int(sess.entry_point)  # static anchor
    assert int(stats2.graph_dists) > 0


def test_hnsw_conversation_work_reduction(hnsw_index, small_corpus):
    conv = jnp.asarray(small_corpus.conversations[0][:, :])
    bk = HNSWBackend(ef=16, up=2)
    _, i_t, st = toploc.conversation(bk, hnsw_index, conv, k=5)
    _, i_p, st_p = toploc.conversation(bk, hnsw_index, conv, k=5,
                                       mode="plain")
    # follow-up turns must do less graph work than plain (no descent)
    t_work = np.asarray(st.graph_dists)[1:].mean()
    p_work = np.asarray(st_p.graph_dists)[1:].mean()
    assert t_work < p_work


def test_intersect_count_basic():
    a = jnp.asarray([1, 2, 3, 4], jnp.int32)
    b = jnp.asarray([3, 4, 5, 6], jnp.int32)
    assert int(intersect_count(a, b)) == 2
    assert int(intersect_count(a, a)) == 4
    pad = jnp.asarray([-1, -1, 1, 2], jnp.int32)
    assert int(intersect_count(pad, a)) == 2
