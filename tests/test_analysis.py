"""Fixture-driven tests for the ``repro.analysis`` invariant linter.

One test per pass: each seeded-violation fixture under
``tests/analysis_fixtures/`` must trigger *exactly* its intended
diagnostic, and the fast passes must report the real tree clean (the
full six-pass sweep is CI's ``python -m repro.analysis --strict`` gate).
"""
import importlib.util
from pathlib import Path

from repro.analysis import run_all
from repro.analysis.project import Project, modules_from_paths

FIXTURES = Path(__file__).parent / "analysis_fixtures"


def _load(name):
    """Import a fixture module by path (fixtures are not a package)."""
    spec = importlib.util.spec_from_file_location(
        f"analysis_fixtures_{name}", FIXTURES / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _marked_line(path: Path, marker: str) -> int:
    hits = [i + 1 for i, ln in enumerate(
        path.read_text().splitlines()) if marker in ln]
    assert len(hits) == 1, (marker, hits)
    return hits[0]


# ---------------------------------------------------------------------------
# pass 1 — trace safety (AST)
# ---------------------------------------------------------------------------


def test_trace_safety_fixture_fires_each_diagnostic():
    from repro.analysis import trace_safety
    path = FIXTURES / "trace_unsafe.py"
    findings = trace_safety.run(modules=modules_from_paths([path]))
    got = {(f.code, f.line) for f in findings}
    expect = {
        ("TS101", _marked_line(path, "MARK:TS101a")),
        ("TS101", _marked_line(path, "MARK:TS101b")),
        ("TS102", _marked_line(path, "MARK:TS102")),
        ("TS103", _marked_line(path, "MARK:TS103")),
        ("TS104", _marked_line(path, "MARK:TS104")),
    }
    assert got == expect, [f.render() for f in findings]
    # every finding points into the fixture file
    assert all(f.path.endswith("trace_unsafe.py") for f in findings)


# ---------------------------------------------------------------------------
# pass 2 — jit-static contract (live registry introspection)
# ---------------------------------------------------------------------------


def test_static_contract_fixture_registry():
    from repro.analysis import static_contract
    bb = _load("bad_backends")
    reg = {cls.name: cls for cls in (
        bb.UnfrozenBackend, bb.IdentityHashBackend, bb.ArrayFieldBackend,
        bb.MissingSurfaceBackend, bb.NoDefaultBackend)}
    findings = static_contract.run(registry=reg)
    codes = {}
    for f in findings:
        for name in reg:
            if f"backend {name!r}" in f.message:
                codes.setdefault(name, set()).add(f.code)
    assert codes == {
        "fx_unfrozen": {"SC201"},
        "fx_identity": {"SC202"},
        "fx_array": {"SC203"},
        "fx_missing": {"SC204"},
        "fx_nodefault": {"SC205"},
    }, [f.render() for f in findings]


def test_static_contract_real_registry_is_clean():
    from repro.analysis import static_contract
    assert static_contract.run() == []


# ---------------------------------------------------------------------------
# pass 3 — retrace / promotion (abstract tracing)
# ---------------------------------------------------------------------------


def test_retrace_fixture_backends():
    from repro.analysis import retrace
    from repro.core import backend as _backend
    bb = _load("bad_backends")
    fx = (bb.DtypeDriftBackend, bb.WeakTypeBackend, bb.CacheChurnBackend)
    for cls in fx:
        _backend.register(cls)
    try:
        for cls, code in ((bb.DtypeDriftBackend, "RT302"),
                          (bb.WeakTypeBackend, "RT303"),
                          (bb.CacheChurnBackend, "RT301")):
            findings = retrace.run(names=[cls.name])
            assert {f.code for f in findings} == {code}, (
                cls.name, [f.render() for f in findings])
    finally:
        for cls in fx:
            _backend._REGISTRY.pop(cls.name, None)


# ---------------------------------------------------------------------------
# pass 4 — Pallas VMEM budget / tile alignment (recorded pallas_call)
# ---------------------------------------------------------------------------


def test_kernel_budget_fixture_overbudget():
    from repro.analysis import kernel_budget
    bk = _load("bad_kernels")
    findings = kernel_budget.run(probes=[("fx_over", bk.overbudget_probe)])
    assert {f.code for f in findings} == {"PK401"}, (
        [f.render() for f in findings])


def test_kernel_budget_fixture_misaligned():
    from repro.analysis import kernel_budget
    bk = _load("bad_kernels")
    findings = kernel_budget.run(probes=[("fx_mis", bk.misaligned_probe)])
    assert {f.code for f in findings} == {"PK402"}, (
        [f.render() for f in findings])


# ---------------------------------------------------------------------------
# pass 5 — shard_map placement specs (recorded shard_map)
# ---------------------------------------------------------------------------


def test_shard_spec_fixture_entries():
    from repro.analysis import shard_specs
    from repro.distributed.retrieval import ShardedIVFScan, shard_ivf_index
    bs = _load("bad_sharding")
    cases = (
        ({"ivf": (shard_ivf_index, bs.MisdeclaredIVFScan, "scan")},
         "SS501"),
        ({"ivf": (bs.shard_ivf_index_partition_centroids, ShardedIVFScan,
                  "scan")},
         "SS502"),
        ({"ivf": (shard_ivf_index, bs.MutableIVFScan, "scan")}, "SS503"),
        ({"ivf": (shard_ivf_index, ShardedIVFScan, "missing_field")},
         "SS503"),
    )
    for reg, code in cases:
        findings = shard_specs.run(registry=reg)
        assert {f.code for f in findings} == {code}, (
            code, [f.render() for f in findings])


# ---------------------------------------------------------------------------
# pass 6 — deprecated-alias usage (AST + live marker discovery)
# ---------------------------------------------------------------------------


def test_deprecation_fixture_flags_alias_uses():
    from repro.analysis import deprecation
    path = FIXTURES / "dep_legacy.py"
    findings = deprecation.run(modules=modules_from_paths([path]))
    got = {(f.code, f.line) for f in findings}
    expect = {
        ("DA601", _marked_line(path, "MARK:DA601-import")),
        ("DA601", _marked_line(path, "MARK:DA601-call")),
    }
    assert got == expect, [f.render() for f in findings]


def test_live_alias_discovery_covers_all_legacy_entry_points():
    from repro.analysis import deprecation
    names = deprecation.live_alias_names()
    assert len(names) == 18
    assert "ivf_start" in names and "hnsw_plain_batch" in names


# ---------------------------------------------------------------------------
# pass 7 — lock-order / lock-discipline (AST)
# ---------------------------------------------------------------------------


def test_lock_order_fixture_fires_each_diagnostic():
    from repro.analysis import lock_order
    path = FIXTURES / "bad_locking.py"
    findings = lock_order.run(modules=modules_from_paths([path]))
    got = {(f.code, f.line) for f in findings}
    expect = {
        ("LK701", _marked_line(path, "MARK:LK701a")),
        ("LK701", _marked_line(path, "MARK:LK701b")),
        ("LK702", _marked_line(path, "MARK:LK702")),
        ("LK703", _marked_line(path, "MARK:LK703a")),
        ("LK703", _marked_line(path, "MARK:LK703b")),
    }
    assert got == expect, [f.render() for f in findings]
    assert all(f.path.endswith("bad_locking.py") for f in findings)


# ---------------------------------------------------------------------------
# pass 8 — guarded fields (AST + @guarded_by declarations)
# ---------------------------------------------------------------------------


def test_guarded_fields_fixture_fires_each_diagnostic():
    from repro.analysis import guarded_fields
    path = FIXTURES / "bad_guards.py"
    findings = guarded_fields.run(modules=modules_from_paths([path]))
    got = {(f.code, f.line) for f in findings}
    expect = {
        ("GF801", _marked_line(path, "MARK:GF801-read")),
        ("GF801", _marked_line(path, "MARK:GF801-write")),
        ("GF802", _marked_line(path, "MARK:GF802")),
    }
    assert got == expect, [f.render() for f in findings]


# ---------------------------------------------------------------------------
# CLI --select validation
# ---------------------------------------------------------------------------


def test_select_unknown_pass_exits_2_with_valid_names(capsys):
    from repro.analysis import all_passes
    from repro.analysis.__main__ import main
    rc = main(["--select", "bogus", "--select", "lock-order"])
    assert rc == 2
    err = capsys.readouterr().err
    assert "unknown pass(es): bogus" in err
    for name in all_passes():
        assert name in err


# ---------------------------------------------------------------------------
# baseline mechanics + the real tree stays clean on the fast passes
# ---------------------------------------------------------------------------


def test_baseline_suppression_and_stale_detection():
    from repro.analysis.findings import Finding, apply_baseline
    f = Finding("p", "TS101", "a/b.py", 3, "traced branch")
    active, suppressed, stale = apply_baseline([f], ["a/b.py:3: TS101*"])
    assert not active and len(suppressed) == 1 and not stale
    active, suppressed, stale = apply_baseline([f], ["never-matches*"])
    assert len(active) == 1 and not suppressed
    assert stale == ["never-matches*"]


def test_tree_is_clean_on_static_passes():
    findings = run_all(Project(), select=[
        "trace-safety", "contract", "deprecated", "kernels",
        "lock-order", "guarded-fields"])
    assert findings == [], [f.render() for f in findings]
