"""End-to-end training loop (launch/train.py) with failure injection."""

from repro.distributed import fault
from repro.launch import train as TR


def test_smoke_train_loop_lm(tmp_path):
    """3 steps of a tiny LM train with checkpointing."""
    steps = TR.run("qwen3-14b", steps=3, smoke=True,
                   ckpt_dir=str(tmp_path), ckpt_every=2, resume=False,
                   injector=fault.FailureInjector([]),
                   shape_overrides=dict(seq_len=32, global_batch=2))
    assert steps == 3
    from repro.checkpoint import checkpoint as C
    assert C.latest_step(str(tmp_path)) == 3


def test_train_loop_restart_resumes(tmp_path):
    inj = fault.FailureInjector([2])

    def attempt(resume):
        return TR.run("yi-9b", steps=4, smoke=True,
                      ckpt_dir=str(tmp_path), ckpt_every=1,
                      resume=resume, injector=inj,
                      shape_overrides=dict(seq_len=32, global_batch=2))

    final = fault.run_with_restarts(attempt)
    assert final == 4
    from repro.checkpoint import checkpoint as C
    assert C.latest_step(str(tmp_path)) == 4


def test_smoke_train_loop_recsys(tmp_path):
    steps = TR.run("dcn-v2", steps=3, smoke=True, ckpt_dir=None,
                   ckpt_every=10, resume=False,
                   injector=fault.FailureInjector([]),
                   shape_overrides=dict(batch=16))
    assert steps == 3
