"""Shared fixtures. NOTE: device count stays 1 here — only
launch/dryrun.py forces 512 host devices (per the brief)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp


def pytest_collection_modifyitems(config, items):
    """``tpu_only`` tests (real Pallas kernel compilation) skip cleanly
    on non-TPU backends instead of erroring at lowering time."""
    if jax.default_backend() == "tpu":
        return
    skip = pytest.mark.skip(
        reason="tpu_only: needs a real TPU backend "
               f"(running on {jax.default_backend()})")
    for item in items:
        if "tpu_only" in item.keywords:
            item.add_marker(skip)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def small_corpus():
    """Topic-clustered unit-norm corpus shared across ANN tests."""
    from repro.data import synthetic as SY
    cfg = SY.WorkloadConfig(n_docs=2000, d=32, n_topics=16,
                            n_conversations=4, turns_per_conversation=6,
                            seed=0)
    return SY.make_workload(cfg)


@pytest.fixture(scope="session")
def ivf_index(small_corpus):
    from repro.core import ivf
    return ivf.build(jnp.asarray(small_corpus.doc_vecs), p=32, iters=5,
                     key=jax.random.PRNGKey(0))


@pytest.fixture(scope="session")
def ivf_pq_index(small_corpus, ivf_index):
    """IVF geometry of ``ivf_index`` + PQ-compressed posting lists."""
    from repro.core import pq
    return pq.build_ivf_pq(ivf_index, jnp.asarray(small_corpus.doc_vecs),
                           m=8, iters=6, key=jax.random.PRNGKey(0))


@pytest.fixture(scope="session")
def hnsw_index(small_corpus):
    from repro.core import hnsw
    return hnsw.build(small_corpus.doc_vecs[:1200], m=8,
                      ef_construction=32, seed=0)
