"""HNSW build + TPU-native beam query."""
import numpy as np
import jax.numpy as jnp

from repro.core import hnsw, ivf


def test_graph_structure(hnsw_index):
    adj = np.asarray(hnsw_index.adj0)
    n = hnsw_index.n
    assert adj.shape[0] == n
    real = adj[adj >= 0]
    assert real.size > 0 and real.max() < n
    # no self loops in level-0 adjacency
    rows = np.arange(n)[:, None].repeat(adj.shape[1], 1)
    assert not np.any((adj == rows) & (adj >= 0))


def test_high_ef_high_recall(hnsw_index, small_corpus):
    docs = jnp.asarray(small_corpus.doc_vecs[:hnsw_index.n])
    q = jnp.asarray(small_corpus.conversations.reshape(-1, 32)[:12])
    ev, ei = ivf.exact_search(docs, q, 10)
    v, i, nd = hnsw.search(hnsw_index, q, ef=64, k=10)
    rec = np.mean([len(set(np.asarray(i[b]).tolist())
                       & set(np.asarray(ei[b]).tolist())) / 10
                   for b in range(q.shape[0])])
    assert rec >= 0.9, rec
    assert np.all(np.asarray(nd) < hnsw_index.n)   # sub-linear work


def test_recall_grows_with_ef(hnsw_index, small_corpus):
    docs = jnp.asarray(small_corpus.doc_vecs[:hnsw_index.n])
    q = jnp.asarray(small_corpus.conversations.reshape(-1, 32)[:8])
    ev, ei = ivf.exact_search(docs, q, 10)
    recalls, works = [], []
    for ef in (4, 16, 64):
        _, i, nd = hnsw.search(hnsw_index, q, ef=ef, k=min(ef, 10))
        k = min(ef, 10)
        rec = np.mean([len(set(np.asarray(i[b]).tolist())
                           & set(np.asarray(ei[b][:k]).tolist())) / k
                       for b in range(q.shape[0])])
        recalls.append(rec)
        works.append(float(np.asarray(nd).mean()))
    assert recalls[-1] >= recalls[0]
    assert works[0] < works[-1]     # ef controls the work knob


def test_entry_override_skips_descent(hnsw_index, small_corpus):
    q = jnp.asarray(small_corpus.conversations[1, :1])
    _, i_full, nd_full = hnsw.search(hnsw_index, q, ef=16, k=5)
    entry = i_full[:, 0].astype(jnp.int32)
    _, i_ov, nd_ov = hnsw.search(hnsw_index, q, ef=16, k=5,
                                 entry_override=entry,
                                 use_entry_override=True)
    # starting at the answer costs less and still finds it
    assert int(nd_ov[0]) < int(nd_full[0])
    assert int(i_ov[0, 0]) == int(i_full[0, 0])


def test_save_load_roundtrip(tmp_path, hnsw_index, small_corpus):
    p = str(tmp_path / "hnsw.npz")
    hnsw.save(hnsw_index, p)
    back = hnsw.load(p)
    q = jnp.asarray(small_corpus.conversations[0, :2])
    v1, i1, _ = hnsw.search(hnsw_index, q, ef=16, k=5)
    v2, i2, _ = hnsw.search(back, q, ef=16, k=5)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
