"""Model-level correctness beyond smoke: decode==forward consistency,
MoE routing invariants, MLA absorbed-decode equivalence, encoder
trainability."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import transformer as T


TINY = T.LMConfig(name="tiny", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_head=16, d_ff=128, vocab=128,
                  loss_chunk=8, remat=False)


def _toks(b=2, s=16, vocab=128, seed=0):
    return jax.random.randint(jax.random.PRNGKey(seed), (b, s), 0, vocab)


@pytest.mark.parametrize("variant", ["dense", "bias", "qknorm", "moe",
                                     "mla_moe"])
def test_decode_matches_forward(variant):
    cfg = TINY
    if variant == "bias":
        cfg = dataclasses.replace(cfg, qkv_bias=True)
    elif variant == "qknorm":
        cfg = dataclasses.replace(cfg, qk_norm=True)
    elif variant == "moe":
        cfg = dataclasses.replace(cfg, n_experts=4, top_k=2, moe_d_ff=96,
                                  capacity_factor=4.0)
    elif variant == "mla_moe":
        cfg = dataclasses.replace(cfg, attn_kind="mla", kv_lora_rank=32,
                                  d_rope=8, n_experts=4, top_k=2,
                                  n_shared=1, moe_d_ff=48,
                                  capacity_factor=4.0)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = _toks(vocab=cfg.vocab)
    logits_full = T.forward(params, cfg, toks)
    logits_pf, cache, clen = T.prefill(params, cfg, toks, max_len=32)
    np.testing.assert_allclose(np.asarray(logits_pf),
                               np.asarray(logits_full[:, -1]),
                               rtol=5e-3, atol=5e-3)
    nxt = jnp.argmax(logits_pf, -1).astype(jnp.int32)
    logits_dec, cache = T.decode_step(params, cfg, cache, nxt, clen)
    ref = T.forward(params, cfg, jnp.concatenate([toks, nxt[:, None]], 1))
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(ref[:, -1]),
                               rtol=8e-3, atol=8e-3)


def test_unroll_equals_scan():
    cfg = TINY
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = _toks()
    a = T.forward(params, cfg, toks)
    b = T.forward(params, dataclasses.replace(cfg, unroll=True), toks)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


def test_loss_chunking_invariant():
    cfg = TINY
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = _toks()
    l1, _ = T.loss_fn(params, cfg, toks, toks)
    l2, _ = T.loss_fn(params, dataclasses.replace(cfg, loss_chunk=16),
                      toks, toks)
    assert float(l1) == pytest.approx(float(l2), rel=1e-5)


def test_moe_routing_invariants():
    cfg = L.MoEConfig(n_experts=4, top_k=2, d_model=16, d_ff=32,
                      capacity_factor=8.0, n_groups=2)
    params = L.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    out, aux = L.moe_apply(params, cfg, x)
    assert out.shape == x.shape
    assert float(aux) >= 1.0 - 1e-5       # aux ≥ 1 (balanced lower bound)
    # with huge capacity nothing drops: output must be nonzero for all
    assert float(jnp.abs(out).sum()) > 0


def test_moe_capacity_drops_tokens():
    """capacity_factor→tiny forces drops; output stays finite."""
    cfg = L.MoEConfig(n_experts=2, top_k=1, d_model=8, d_ff=16,
                      capacity_factor=0.1, n_groups=1)
    params = L.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 8))
    out, _ = L.moe_apply(params, cfg, x)
    assert bool(jnp.isfinite(out).all())
    # some token rows should be exactly zero (dropped)
    norms = jnp.linalg.norm(out[0], axis=-1)
    assert float((norms == 0).sum()) > 0


def test_moe_groups_equivalence_statistics():
    """Group count changes routing locality, not scale of output."""
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 16))
    outs = []
    for g in (1, 4):
        cfg = L.MoEConfig(n_experts=4, top_k=2, d_model=16, d_ff=32,
                          capacity_factor=8.0, n_groups=g)
        params = L.moe_init(jax.random.PRNGKey(0), cfg)
        out, _ = L.moe_apply(params, cfg, x)
        outs.append(float(jnp.std(out)))
    assert outs[0] == pytest.approx(outs[1], rel=0.2)


def test_rope_relative_shift():
    """RoPE: shifting positions of q and k together preserves scores."""
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 4, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 4, 16))
    p0 = jnp.arange(4)[None, None]
    p1 = p0 + 7
    def scores(pos):
        qr = L.apply_rope(q, pos)
        kr = L.apply_rope(k, pos)
        return jnp.einsum("bhsd,bhtd->bhst", qr, kr)
    np.testing.assert_allclose(np.asarray(scores(p0)),
                               np.asarray(scores(p1)), rtol=1e-4,
                               atol=1e-4)


def test_encoder_learns_in_batch():
    from repro.models import encoder as E
    from repro.optim import optimizers as O
    cfg = E.EncoderConfig(name="t", n_layers=2, d_model=32, n_heads=4,
                          d_ff=64, vocab=64, max_len=8, out_dim=16)
    params = E.init_params(cfg, jax.random.PRNGKey(0))
    opt = O.adamw(1e-3)
    state = opt.init(params)
    rng = np.random.default_rng(0)
    # trivially-separable batch: query tokens == doc tokens
    toks = jnp.asarray(rng.integers(3, 64, (8, 8)).astype(np.int32))
    batch = {"q_tokens": toks, "q_mask": jnp.ones((8, 8), bool),
             "d_tokens": toks, "d_mask": jnp.ones((8, 8), bool)}

    @jax.jit
    def step(p, s):
        (loss, m), g = jax.value_and_grad(E.contrastive_loss,
                                          has_aux=True)(p, cfg, batch)
        up, s = opt.update(g, s, p)
        return O.apply_updates(p, up), s, loss

    losses = []
    for _ in range(30):
        params, state, loss = step(params, state)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.8
