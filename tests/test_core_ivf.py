"""Core IVF + k-means invariants."""
import numpy as np
import jax.numpy as jnp

from repro.core import ivf, kmeans


def test_build_partitions_all_docs(ivf_index, small_corpus):
    assert int(ivf_index.list_sizes.sum()) == small_corpus.doc_vecs.shape[0]
    ids = np.asarray(ivf_index.list_ids)
    real = ids[ids >= 0]
    assert len(np.unique(real)) == small_corpus.doc_vecs.shape[0]


def test_balanced_capacity(ivf_index, small_corpus):
    n, p = small_corpus.doc_vecs.shape[0], ivf_index.p
    cap = int(1.3 * n / p + 1)
    assert int(ivf_index.list_sizes.max()) <= cap
    assert ivf_index.lmax <= cap


def test_full_probe_equals_exact(ivf_index, small_corpus):
    """nprobe == p must reproduce exhaustive search (paper §2: np=p)."""
    q = jnp.asarray(small_corpus.conversations[:2, 0])
    ev, ei = ivf.exact_search(jnp.asarray(small_corpus.doc_vecs), q, 10)
    sv, si, _ = ivf.search(ivf_index, q, nprobe=ivf_index.p, k=10)
    np.testing.assert_array_equal(np.asarray(si), np.asarray(ei))
    np.testing.assert_allclose(np.asarray(sv), np.asarray(ev), rtol=1e-5)


def test_recall_monotone_in_nprobe(ivf_index, small_corpus):
    q = jnp.asarray(small_corpus.conversations.reshape(-1, 32)[:16])
    ev, ei = ivf.exact_search(jnp.asarray(small_corpus.doc_vecs), q, 10)
    recalls = []
    for npb in (1, 4, 16, 32):
        _, si, _ = ivf.search(ivf_index, q, nprobe=npb, k=10)
        r = np.mean([len(set(np.asarray(si[i]).tolist())
                         & set(np.asarray(ei[i]).tolist())) / 10
                     for i in range(q.shape[0])])
        recalls.append(r)
    assert all(b >= a - 1e-9 for a, b in zip(recalls, recalls[1:]))
    assert recalls[-1] == 1.0


def test_search_stats_counts(ivf_index):
    q = jnp.ones((3, ivf_index.d)) / np.sqrt(ivf_index.d)
    _, _, st = ivf.search(ivf_index, q, nprobe=4, k=5)
    assert st.centroid_dists.shape == (3,)
    assert int(st.centroid_dists[0]) == ivf_index.p
    sizes = np.asarray(ivf_index.list_sizes)
    assert np.all(np.asarray(st.list_dists) <= 4 * sizes.max())
    assert np.all(np.asarray(st.list_dists) > 0)


def test_cached_search_matches_full_when_cache_is_all(ivf_index,
                                                      small_corpus):
    """h == p: cached search must equal plain search exactly."""
    q = jnp.asarray(small_corpus.conversations[0, :3])
    cache_ids, cache_vecs = ivf.make_cache(ivf_index, q[0], h=ivf_index.p)
    v1, i1, _ = ivf.search(ivf_index, q, nprobe=8, k=10)
    v2, i2, sel, _ = ivf.search_cached(ivf_index, cache_ids, cache_vecs,
                                       q, nprobe=8, k=10)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_kmeans_balance_respects_capacity(rng):
    x = jnp.asarray(rng.normal(size=(500, 16)).astype(np.float32))
    res = kmeans.fit_balanced(x, 8, iters=4, capacity_factor=1.2)
    cap = int(1.2 * 500 / 8 + 1)
    assert int(res.sizes.max()) <= cap
    assert int(res.sizes.sum()) == 500
    # every point assigned to a real cluster
    assert np.all(np.asarray(res.assignment) >= 0)
    assert np.all(np.asarray(res.assignment) < 8)
