"""Segmented mutable corpus (core.segment): the compaction contract.

The hard bar under test: after any add/delete/compact history,
``compact()`` leaves an index **bit-identical to ``rebuild()``** — the
independent from-scratch construction over the same corpus and mutation
set — for all three backends (ids, scores, and every ``TurnStats``
field).  Plus the delta-path guarantees: an empty delta reproduces the
wrapped backend bit for bit, the base-vs-delta merge is deterministic at
any fill level, and tombstoned documents are masked out of both scans
immediately.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import backend as B
from repro.core import hnsw, ivf, pq, segment as S, toploc

K = 5
D = 16
N = 240
CAP = 16
HKW = dict(ef_construction=32, seed=0)     # hnsw build/compact params


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(7)
    docs = rng.standard_normal((N + 24, D)).astype(np.float32)
    docs /= np.linalg.norm(docs, axis=1, keepdims=True)
    return docs[:N], docs[N:]              # (base docs, add pool)


@pytest.fixture(scope="module")
def seg_ivf_index(corpus):
    return ivf.build(jnp.asarray(corpus[0]), 16, iters=4,
                     key=jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def seg_pq_index(seg_ivf_index, corpus):
    return pq.build_ivf_pq(seg_ivf_index, jnp.asarray(corpus[0]), 8)


@pytest.fixture(scope="module")
def seg_hnsw_index(corpus):
    return hnsw.build(corpus[0], m=8, **HKW)


def _backends(seg_ivf_index, seg_pq_index, seg_hnsw_index):
    knobs = dict(h=8, nprobe=4, alpha=0.5)
    return [
        ("ivf", B.make("ivf", **knobs), seg_ivf_index, {}),
        ("ivf_pq", B.make("ivf_pq", rerank=16, **knobs), seg_pq_index, {}),
        ("hnsw", B.make("hnsw", ef=16, up=2), seg_hnsw_index, HKW),
    ]


def _tree_equal(a, b):
    la = jax.tree.leaves(a, is_leaf=lambda x: x is None)
    lb = jax.tree.leaves(b, is_leaf=lambda x: x is None)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        if x is None or y is None:
            assert x is None and y is None
            continue
        assert np.array_equal(np.asarray(x), np.asarray(y))


def _conversation(backend, index, queries, k=K):
    """Drive a short conversation; returns (v, i, stats) stacked."""
    out_v, out_i, out_s = [], [], []
    v, i, sess, st = toploc.start(backend, index, queries[0], k=k)
    out_v.append(v), out_i.append(i), out_s.append(st)
    for q in queries[1:]:
        v, i, sess, st = toploc.step(backend, index, sess, q, k=k)
        out_v.append(v), out_i.append(i), out_s.append(st)
    return (np.stack([np.asarray(x) for x in out_v]),
            np.stack([np.asarray(x) for x in out_i]),
            [jax.tree.map(np.asarray, s) for s in out_s])


# ----------------------------------------------------- empty delta

@pytest.mark.parametrize("which", ["ivf", "ivf_pq", "hnsw"])
def test_empty_delta_reproduces_inner_bitwise(
        which, corpus, seg_ivf_index, seg_pq_index, seg_hnsw_index):
    """A cap-row delta at fill 0 must not perturb a single bit — scores,
    ids, or TurnStats — relative to the unwrapped backend."""
    name, inner, index, _ = next(
        e for e in _backends(seg_ivf_index, seg_pq_index, seg_hnsw_index)
        if e[0] == which)
    seg = S.make_segmented(inner, index, cap=CAP)
    wrap = S.SegmentedBackend(inner=inner)
    qs = jnp.asarray(corpus[0][3:6])
    v1, i1, s1 = _conversation(inner, index, qs)
    v2, i2, s2 = _conversation(wrap, seg, qs)
    np.testing.assert_array_equal(v1, v2)
    np.testing.assert_array_equal(i1, i2)
    for a, b in zip(s1, s2):
        _tree_equal(a, b)


# ------------------------------------------- compaction bit-identity

@pytest.mark.parametrize("which", ["ivf", "ivf_pq", "hnsw"])
def test_compact_bit_identical_to_rebuild(
        which, corpus, seg_ivf_index, seg_pq_index, seg_hnsw_index):
    """compact() == rebuild() at the array level AND at the query level
    (ids, scores, TurnStats) after adds + deletes."""
    name, inner, index, kw = next(
        e for e in _backends(seg_ivf_index, seg_pq_index, seg_hnsw_index)
        if e[0] == which)
    base_docs, pool = corpus
    seg = S.make_segmented(inner, index, cap=CAP)
    seg, ids = S.add_documents(seg, pool[:6])
    assert list(ids) == list(range(N, N + 6))
    dead = [3, N + 1]                         # one base doc, one delta doc
    seg = S.delete_documents(inner, seg, dead)

    compacted = S.compact(inner, seg, **kw)
    rebuilt = S.rebuild(inner, index, pool[:6], dead, cap=CAP, **kw)
    _tree_equal(compacted, rebuilt)

    wrap = S.SegmentedBackend(inner=inner)
    qs = jnp.asarray(np.concatenate([pool[:2], base_docs[9:10]]))
    v1, i1, s1 = _conversation(wrap, compacted, qs)
    v2, i2, s2 = _conversation(wrap, rebuilt, qs)
    np.testing.assert_array_equal(v1, v2)
    np.testing.assert_array_equal(i1, i2)
    for a, b in zip(s1, s2):
        _tree_equal(a, b)
    assert 3 not in i1 and N + 1 not in i1


@pytest.mark.parametrize("which", ["ivf", "ivf_pq", "hnsw"])
def test_multi_cycle_compaction_equals_one_rebuild(
        which, corpus, seg_ivf_index, seg_pq_index, seg_hnsw_index):
    """Two interleaved add/delete/compact cycles fold to exactly the
    state one rebuild derives from the whole mutation history — ids are
    never renumbered and every cycle preserves the invariant."""
    name, inner, index, kw = next(
        e for e in _backends(seg_ivf_index, seg_pq_index, seg_hnsw_index)
        if e[0] == which)
    _, pool = corpus
    seg = S.make_segmented(inner, index, cap=CAP)
    seg, _ = S.add_documents(seg, pool[:5])
    seg = S.delete_documents(inner, seg, [N + 2, 11])
    seg = S.compact(inner, seg, **kw)
    seg, ids2 = S.add_documents(seg, pool[5:9])
    assert list(ids2) == list(range(N + 5, N + 9))   # monotone across cycles
    seg = S.delete_documents(inner, seg, [N + 7, 4])
    seg = S.compact(inner, seg, **kw)

    rebuilt = S.rebuild(inner, index, pool[:9], [N + 2, 11, N + 7, 4],
                        cap=CAP, **kw)
    _tree_equal(seg, rebuilt)


def test_compact_without_mutations_is_identity(seg_ivf_index):
    inner = B.make("ivf", h=8, nprobe=4, alpha=0.5)
    seg = S.make_segmented(inner, seg_ivf_index, cap=CAP)
    _tree_equal(S.compact(inner, seg), seg)


# ----------------------------------------------- delta determinism

def test_merge_deterministic_at_any_fill_level(corpus, seg_ivf_index):
    """The same live delta docs produce the same merged ranking bit for
    bit regardless of segment capacity (trailing empty rows never shift
    the order), and repeated queries are reproducible."""
    inner = B.make("ivf", h=8, nprobe=4, alpha=0.5)
    wrap = S.SegmentedBackend(inner=inner)
    _, pool = corpus
    q = jnp.asarray(pool[:2])
    outs = []
    for cap in (8, 16):
        seg = S.make_segmented(inner, seg_ivf_index, cap=cap)
        seg, _ = S.add_documents(seg, pool[:3])
        outs.append(wrap.plain_batch(seg, q, k=K))
    (v1, i1, s1), (v2, i2, s2) = outs
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    _tree_equal(s1, s2)
    v3, i3, _ = wrap.plain_batch(seg, q, k=K)
    np.testing.assert_array_equal(np.asarray(v2), np.asarray(v3))
    np.testing.assert_array_equal(np.asarray(i2), np.asarray(i3))


def test_merge_ties_break_by_append_order(corpus, seg_ivf_index):
    """Two identical delta rows score identically; the deterministic
    merge must rank the earlier append (smaller id) first."""
    inner = B.make("ivf", h=8, nprobe=4, alpha=0.5)
    wrap = S.SegmentedBackend(inner=inner)
    _, pool = corpus
    seg = S.make_segmented(inner, seg_ivf_index, cap=CAP)
    seg, _ = S.add_documents(seg, pool[:1])
    seg, _ = S.add_documents(seg, pool[:1])          # exact duplicate
    _, i, _ = wrap.plain_batch(seg, jnp.asarray(pool[:1]), k=K)
    i = np.asarray(i)[0]
    assert list(i[:2]) == [N, N + 1]


# ------------------------------------------------------- tombstones

def test_delete_masks_base_and_delta_immediately(corpus, seg_ivf_index):
    inner = B.make("ivf", h=8, nprobe=4, alpha=0.5)
    wrap = S.SegmentedBackend(inner=inner)
    base_docs, pool = corpus
    seg = S.make_segmented(inner, seg_ivf_index, cap=CAP)
    seg, _ = S.add_documents(seg, pool[:2])
    # both a base doc (its own vector as query -> top hit) and a delta doc
    _, i_b, _ = wrap.plain_batch(seg, jnp.asarray(base_docs[17:18]), k=K)
    _, i_d, _ = wrap.plain_batch(seg, jnp.asarray(pool[:1]), k=K)
    assert 17 in np.asarray(i_b) and N in np.asarray(i_d)
    seg = S.delete_documents(inner, seg, [17, N])
    _, i_b, _ = wrap.plain_batch(seg, jnp.asarray(base_docs[17:18]), k=K)
    _, i_d, _ = wrap.plain_batch(seg, jnp.asarray(pool[:1]), k=K)
    assert 17 not in np.asarray(i_b) and N not in np.asarray(i_d)


def test_delete_is_idempotent_and_validated(seg_ivf_index):
    inner = B.make("ivf", h=8, nprobe=4, alpha=0.5)
    seg = S.make_segmented(inner, seg_ivf_index, cap=CAP)
    seg = S.delete_documents(inner, seg, [5])
    seg2 = S.delete_documents(inner, seg, [5])
    _tree_equal(seg, seg2)
    with pytest.raises(ValueError, match="unassigned"):
        S.delete_documents(inner, seg, [N])          # delta row not filled
    with pytest.raises(ValueError, match="unassigned"):
        S.delete_documents(inner, seg, [-1])


def test_hnsw_deleted_nodes_still_route_the_beam(corpus, seg_hnsw_index):
    """The standard HNSW tombstone scheme: a deleted node is masked out
    of the result top-k but keeps routing, so survivors' reachability is
    unchanged — and the graph stays bit-identical to a fresh build."""
    inner = B.make("hnsw", ef=16, up=2)
    seg = S.make_segmented(inner, seg_hnsw_index, cap=CAP)
    seg = S.delete_documents(inner, seg, [int(seg_hnsw_index.entry_point)])
    assert seg.base.deleted is not None
    # the graph topology is untouched — only the mask differs
    for f in ("vectors", "adj0", "upper_adj", "entry_point", "node_level"):
        np.testing.assert_array_equal(
            np.asarray(getattr(seg.base, f)),
            np.asarray(getattr(seg_hnsw_index, f)))
    wrap = S.SegmentedBackend(inner=inner)
    _, i, _ = wrap.plain_batch(seg, jnp.asarray(corpus[0][:2]), k=K)
    assert int(seg_hnsw_index.entry_point) not in np.asarray(i)


# --------------------------------------------------- hnsw insertion

def test_hnsw_insert_equals_build(corpus):
    docs = corpus[0]
    partial = hnsw.build(docs[:200], m=8, **HKW)
    grown = hnsw.insert(partial, docs[200:], **HKW)
    full = hnsw.build(docs, m=8, **HKW)
    _tree_equal(grown, full)


def test_hnsw_insert_rejects_mismatched_stream(corpus):
    docs = corpus[0]
    partial = hnsw.build(docs[:200], m=8, ef_construction=32, seed=0)
    with pytest.raises(ValueError, match="level stream"):
        hnsw.insert(partial, docs[200:], ef_construction=32, seed=1)


# ------------------------------------------------------- guard rails

def test_add_overflow_and_cap_validation(seg_ivf_index, corpus):
    inner = B.make("ivf", h=8, nprobe=4, alpha=0.5)
    with pytest.raises(ValueError, match="cap"):
        S.make_segmented(inner, seg_ivf_index, cap=0)
    seg = S.make_segmented(inner, seg_ivf_index, cap=2)
    seg, _ = S.add_documents(seg, corpus[1][:2])
    with pytest.raises(ValueError, match="overflow"):
        S.add_documents(seg, corpus[1][2:3])


def test_exact_backend_unsupported(corpus):
    inner = B.make("exact")
    with pytest.raises(NotImplementedError, match="exact"):
        S.make_segmented(inner, jnp.asarray(corpus[0]), cap=4)


def test_segmented_registered_and_jit_static(seg_ivf_index):
    assert "segmented" in B.names()
    inner = B.make("ivf", h=8, nprobe=4, alpha=0.5)
    a = B.make("segmented", inner=inner)
    b = B.make("segmented", inner=B.make("ivf", h=8, nprobe=4, alpha=0.5))
    assert a == b and hash(a) == hash(b)
    assert a.stateful is True
    assert a.index_kwarg == "segmented_index"
