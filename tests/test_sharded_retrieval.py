"""Device-sharded retrieval: bit-equality with the single-device path.

The contract under test (distributed/retrieval.py): partitioning the
corpus over a mesh — IVF posting lists, PQ code lists + re-rank corpus,
the HNSW vector corpus — changes *where* distances are computed and
nothing else.  Scores, ids, every ``TurnStats`` counter and the session
state must equal the single-device path bit for bit, at every shard
count, for all three backends, across a full 8-turn conversation.

Under the default 1-device run these tests still exercise the complete
``shard_map`` + collective path on a 1-shard mesh; the CI job with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` runs them at
2/4/8 shards.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import hnsw, ivf, pq, toploc
from repro.core.backend import HNSWBackend, IVFBackend, IVFPQBackend
from repro.core.topk import distributed_topk_ordered
from repro.distributed import retrieval as R
from repro.serving.engine import (BatchedConversationalSearchEngine,
                                  ConversationalSearchEngine, ServingConfig)

SHARD_COUNTS = [s for s in (1, 2, 4, 8) if s <= jax.device_count()]
T = 8            # acceptance: 8-turn conversations
K, H, NPROBE, EF, UP, RR, ALPHA = 10, 16, 4, 16, 2, 32, 0.3


@pytest.fixture(scope="module")
def wl8():
    """Topic-clustered workload with 8-turn conversations."""
    from repro.data import synthetic as SY
    return SY.make_workload(SY.WorkloadConfig(
        n_docs=1500, d=32, n_topics=16, n_conversations=4,
        turns_per_conversation=T, seed=7))


@pytest.fixture(scope="module")
def idx8(wl8):
    return ivf.build(jnp.asarray(wl8.doc_vecs), p=32, iters=4,
                     key=jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def pq8(wl8, idx8):
    return pq.build_ivf_pq(idx8, jnp.asarray(wl8.doc_vecs), m=8, iters=4,
                           key=jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def hnsw8(wl8):
    return hnsw.build(wl8.doc_vecs, m=8, ef_construction=32, seed=0)


def _assert_stats_equal(ref, got, ctx):
    for f in toploc.TurnStats._fields:
        assert bool((jnp.asarray(getattr(ref, f))
                     == jnp.asarray(getattr(got, f))).all()), (f, ctx)


# ------------------------------------------------- toploc step bit-equality

@pytest.mark.parametrize("shards", SHARD_COUNTS)
def test_ivf_sharded_conversation_bit_identical(idx8, wl8, shards):
    mesh = R.retrieval_mesh(shards)
    bk = IVFBackend(h=H, nprobe=NPROBE, alpha=ALPHA)
    sbk, sidx = R.shard_backend(mesh, bk, idx8)
    conv = jnp.asarray(wl8.conversations[0])
    v, i, s, st = toploc.start(bk, idx8, conv[0], k=K)
    sv, si, ss, sst = toploc.start(sbk, sidx, conv[0], k=K)
    assert bool((v == sv).all()) and bool((i == si).all())
    _assert_stats_equal(st, sst, ("start", shards))
    for t in range(1, T):
        v, i, s, st = toploc.step(bk, idx8, s, conv[t], k=K)
        sv, si, ss, sst = toploc.step(sbk, sidx, ss, conv[t], k=K)
        assert bool((v == sv).all()) and bool((i == si).all()), t
        _assert_stats_equal(st, sst, (t, shards))
    for f in toploc.IVFSession._fields:
        assert bool((getattr(s, f) == getattr(ss, f)).all()), f


@pytest.mark.parametrize("shards", SHARD_COUNTS)
def test_ivf_pq_sharded_conversation_bit_identical(pq8, wl8, shards):
    mesh = R.retrieval_mesh(shards)
    bk = IVFPQBackend(h=H, nprobe=NPROBE, alpha=ALPHA, rerank=RR)
    sbk, sidx = R.shard_backend(mesh, bk, pq8)
    conv = jnp.asarray(wl8.conversations[1])
    v, i, s, st = toploc.start(bk, pq8, conv[0], k=K)
    sv, si, ss, sst = toploc.start(sbk, sidx, conv[0], k=K)
    assert bool((v == sv).all()) and bool((i == si).all())
    _assert_stats_equal(st, sst, ("start", shards))
    for t in range(1, T):
        v, i, s, st = toploc.step(bk, pq8, s, conv[t], k=K)
        sv, si, ss, sst = toploc.step(sbk, sidx, ss, conv[t], k=K)
        assert bool((v == sv).all()) and bool((i == si).all()), t
        _assert_stats_equal(st, sst, (t, shards))


@pytest.mark.parametrize("shards", SHARD_COUNTS)
def test_hnsw_sharded_conversation_bit_identical(hnsw8, wl8, shards):
    mesh = R.retrieval_mesh(shards)
    bk = HNSWBackend(ef=EF, up=UP)
    sbk, sidx = R.shard_backend(mesh, bk, hnsw8)
    conv = jnp.asarray(wl8.conversations[2])
    v, i, s, st = toploc.start(bk, hnsw8, conv[0], k=K)
    sv, si, ss, sst = toploc.start(sbk, sidx, conv[0], k=K)
    assert bool((v == sv).all()) and bool((i == si).all())
    _assert_stats_equal(st, sst, ("start", shards))
    for t in range(1, T):
        v, i, s, st = toploc.step(bk, hnsw8, s, conv[t], k=K)
        sv, si, ss, sst = toploc.step(sbk, sidx, ss, conv[t], k=K)
        assert bool((v == sv).all()) and bool((i == si).all()), t
        _assert_stats_equal(st, sst, (t, shards))
    assert int(s.entry_point) == int(ss.entry_point)


def test_sharded_batched_step_matches_sequential(idx8, wl8):
    """Mixed first/follow-up batch on the sharded scan reproduces the
    sharded sequential rows (the is_first select logic composes with
    shard_map inside the batch-wide lax.cond gate)."""
    mesh = R.retrieval_mesh(SHARD_COUNTS[-1])
    bk = IVFBackend(h=H, nprobe=NPROBE, alpha=ALPHA)
    sbk, sidx = R.shard_backend(mesh, bk, idx8)
    q0 = jnp.asarray(wl8.conversations[:4, 0])
    _, _, sess0, _ = toploc.start_batch(sbk, sidx, q0, k=K)
    first = jnp.asarray([True, False, True, False])
    qmix = jnp.where(first[:, None], q0, jnp.asarray(wl8.conversations[:4, 1]))
    mv, mi, _, mst = toploc.step_batch(sbk, sidx, sess0, qmix, k=K,
                                       is_first=first)
    for b in range(4):
        if bool(first[b]):
            rv, ri, _, rst = toploc.start(bk, idx8, q0[b], k=K)
        else:
            sb = jax.tree.map(lambda a: a[b], sess0)
            rv, ri, _, rst = toploc.step(
                bk, idx8, sb, jnp.asarray(wl8.conversations[b, 1]), k=K)
        assert bool((mv[b] == rv).all()) and bool((mi[b] == ri).all()), b


# ------------------------------------------------------- engine wiring

def _records_key(recs):
    return sorted((r.conv_id, r.turn, r.centroid_dists, r.list_dists,
                   r.graph_dists, r.code_dists, r.refreshed, r.i0)
                  for r in recs)


@pytest.mark.parametrize("backend,strategy", [
    ("ivf", "toploc+"), ("ivf", "plain"),
    ("ivf_pq", "toploc+"), ("hnsw", "toploc"),
])
def test_sharded_engine_matches_unsharded(wl8, idx8, pq8, hnsw8, backend,
                                          strategy):
    base = dict(backend=backend, strategy=strategy, nprobe=NPROBE, h=H,
                alpha=ALPHA, ef_search=EF, up=UP, k=K, rerank=RR)
    seq = ConversationalSearchEngine(
        ServingConfig(**base), ivf_index=idx8, ivf_pq_index=pq8,
        hnsw_index=hnsw8)
    shd = ConversationalSearchEngine(
        ServingConfig(**base, shards=SHARD_COUNTS[-1]), ivf_index=idx8,
        ivf_pq_index=pq8, hnsw_index=hnsw8)
    for t in range(T):
        for c in range(3):
            qv = jnp.asarray(wl8.conversations[c, t])
            v0, i0 = seq.query(f"c{c}", qv)
            v1, i1 = shd.query(f"c{c}", qv)
            np.testing.assert_array_equal(v0, v1)
            np.testing.assert_array_equal(i0, i1)
    assert _records_key(seq.records) == _records_key(shd.records)


@pytest.mark.parametrize("backend", ["ivf", "ivf_pq", "hnsw"])
def test_sharded_batched_engine_matches_unsharded_sequential(
        wl8, idx8, pq8, hnsw8, backend):
    """The full serving stack — MicroBatcher flush, SessionStore slab,
    batched step, sharded scan — stays bit-identical to the unsharded
    sequential oracle."""
    strategy = "toploc" if backend == "hnsw" else "toploc+"
    base = dict(backend=backend, strategy=strategy, nprobe=NPROBE, h=H,
                alpha=ALPHA, ef_search=EF, up=UP, k=K, rerank=RR)
    seq = ConversationalSearchEngine(
        ServingConfig(**base), ivf_index=idx8, ivf_pq_index=pq8,
        hnsw_index=hnsw8)
    bat = BatchedConversationalSearchEngine(
        ServingConfig(**base, shards=SHARD_COUNTS[-1]), ivf_index=idx8,
        ivf_pq_index=pq8, hnsw_index=hnsw8, max_batch=4, max_wait_s=1e-4)
    for t in range(T):
        futs = []
        for c in range(3):          # 3 rows → padded to bucket 4
            qv = jnp.asarray(wl8.conversations[c, t])
            futs.append((*seq.query(f"c{c}", qv),
                         bat.submit(f"c{c}", qv)))
        bat.drain()
        for sv, si, fut in futs:
            bv, bi = fut.result(timeout=30)
            np.testing.assert_array_equal(sv, bv)
            np.testing.assert_array_equal(si, bi)
    assert _records_key(seq.records) == _records_key(bat.records)


# --------------------------------------------------- building blocks

def test_distributed_topk_ordered_breaks_ties_by_position():
    """Equal scores must resolve by global flat position (the single-
    device lax.top_k order), not by shard order."""
    from repro import compat
    from jax.sharding import PartitionSpec as P
    shards = SHARD_COUNTS[-1]
    mesh = R.retrieval_mesh(shards)
    # every shard offers the same score; positions distinguish them
    v = jnp.tile(jnp.asarray([[1.0, 0.5]]), (1, shards))       # (1, 2S)
    pos = jnp.arange(2 * shards, dtype=jnp.int32)[None]
    pos = pos.at[0, 0].set(100)      # shard 0's best has a HIGH position
    ids = jnp.arange(2 * shards, dtype=jnp.int32)[None] + 10

    def f(v, p, i):
        return distributed_topk_ordered(v, p, i, 2, "model")

    out_v, out_i = compat.shard_map(
        f, mesh=mesh, in_specs=(P(None, "model"), P(None, "model"),
                                P(None, "model")),
        out_specs=(P(None, None), P(None, None)), check_vma=False)(
            v, pos, ids)
    # the tie at 1.0 resolves to the LOWEST position, which is not
    # shard 0's entry (pos 100) when more than one shard ties
    if shards > 1:
        assert np.asarray(out_v).tolist() == [[1.0, 1.0]]
        assert int(np.asarray(out_i)[0, 0]) == 12   # shard 1's pos-2 entry
    else:
        assert np.asarray(out_v)[0].tolist() == [1.0, 0.5]
        assert int(np.asarray(out_i)[0, 0]) == 10


def test_sharded_index_padding_is_inert(idx8, wl8):
    """Padded partitions (p not divisible by S) are never selected and
    contribute no work."""
    # p=32 on 3 shards → pad to 33; needs a 3-shard mesh
    if jax.device_count() < 3:
        pytest.skip("needs >= 3 devices to make padding observable")
    mesh = R.retrieval_mesh(3)
    sidx = R.shard_ivf_index(mesh, idx8)
    assert sidx.list_ids.shape[0] % 3 == 0
    assert sidx.centroids.shape[0] == idx8.p      # centroids unpadded
    scan = R.ShardedIVFScan(mesh)
    q = jnp.asarray(wl8.conversations[0, :2])
    cs = q @ idx8.centroids.T
    _, sel = jax.lax.top_k(cs, NPROBE)
    v, i, real = scan(sidx, q, sel, K)
    rv, ri, rreal = ivf._scan_lists(idx8, q, sel, K)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(rv))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(real), np.asarray(rreal))


def test_per_shard_list_work_partitions_total(idx8, wl8):
    """Per-device work sums to the single-device total and shrinks with
    the shard count (the fig4 measurement helper)."""
    sizes = np.asarray(idx8.list_sizes)
    q = jnp.asarray(wl8.conversations[0, 0])
    cs = idx8.centroids @ q
    _, sel = jax.lax.top_k(cs, 16)
    sel = np.asarray(sel)
    total = sizes[sel].sum()
    for s in (1, 2, 4, 8):
        work = R.per_shard_list_work(sizes, sel, s)
        assert work.shape == (s,)
        assert work.sum() == total
    assert R.per_shard_list_work(sizes, sel, 8).max() < total


def test_retrieval_mesh_validates_device_count():
    with pytest.raises(ValueError, match="shards"):
        R.retrieval_mesh(jax.device_count() + 1)
